//! Sharded serving tier: M coordinator shards behind one router, each a
//! full pipeline replica, with paged-KV admission.
//!
//! This is the layer between requests and rounds. One [`Shard`] owns one
//! [`PipelineSim`] pipeline (the same per-pipeline hardware every prior
//! subsystem models) plus its KV capacity, and serves its resident
//! sequences with fused group rounds exactly like
//! [`OracleFleet`](super::OracleFleet) — earliest-ready-first packing
//! via [`batcher::pack_earliest_ready`], one [`PipelineSim::group_pass`]
//! per round. [`ShardTier`] places each arriving request on a shard
//! ([`Placement::LeastLoaded`] through the id-keyed [`Router`], or
//! [`Placement::Hash`] — a static partition equivalent to M independent
//! coordinators) and advances every shard event-by-event in arrival
//! order, so the whole run is a pure function of (config, arrival
//! order): committed streams are byte-identical run-to-run for a fixed
//! placement — and in fact placement-independent outright, because every
//! stochastic draw is keyed by (seed, request id, position), never by
//! which shard or when it ran.
//!
//! # KV admission: slots vs pages
//!
//! In slot mode a shard admits at most `slots` sequences — the
//! worst-case reservation the engine-backed [`KvPool`](crate::model::KvPool)
//! makes. In paged mode ([`TierConfig::paged`]) the same token capacity
//! backs a [`PagedKvPool`]: admission needs only the *working-set* pages
//! of the prompt, growth allocates one page at a time, and a page fault
//! evicts the least-recently-scheduled resident sequence outside the
//! current group (its pages free; its host state — committed tokens,
//! controller, pre-draft pool — stays). Readmission re-allocates pages
//! for the committed prefix and charges one recompute pass replaying it
//! through the pipeline. More admitted sequences ⇒ wider fused groups ⇒
//! the paper's Eq. 5 sync amortization actually gets its `B` — that is
//! the p99-TTFT / throughput win `benches/ablation_shard.rs` pins.
//!
//! # Hot-path contract
//!
//! [`Shard::serve_round`] is a round-loop root for dsd-lint's
//! allocation walk and for `tests/alloc_budget.rs`: a steady-state round
//! with no page fault performs zero heap allocations (packing buffers
//! are reused, page growth pops a pre-sized free list into a
//! pre-reserved table). Admission, eviction, and readmission are
//! documented budget exceptions, like prefill.

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::cluster::clock::Nanos;
use crate::cluster::sim::PipelineSim;
use crate::coordinator::batcher::pack_earliest_ready;
use crate::coordinator::overlap::{OracleChainDecoder, OracleConfig, OraclePrep, OracleRound};
use crate::coordinator::router::{Placement, Router, RoutePolicy};
use crate::metrics::Histogram;
use crate::model::kv_paged::{Grow, PagedKvPool};
use crate::spec::AcceptanceStats;
use crate::trace::TraceKey;
use crate::workload::Request;

/// Extra tokens of KV coverage a sequence may need past
/// `prompt + target`: the widest grid γ plus the bonus token of its
/// final (possibly overshooting) round. Generation targets are clamped
/// so `prompt + target + KV_MARGIN <= slot_tokens`, which is what makes
/// a single sequence always fit its shard's pool (the eviction
/// fallback's termination guarantee).
pub const KV_MARGIN: usize = 16;

/// Serving-tier configuration (engine-free path).
#[derive(Debug, Clone)]
pub struct TierConfig {
    /// Coordinator shards; each is a full pipeline replica.
    pub shards: usize,
    pub placement: Placement,
    /// Paged KV admission (false = worst-case slot reservation).
    pub paged: bool,
    /// Tokens per KV page.
    pub page_tokens: usize,
    /// Worst-case slots per shard; both modes size their capacity from
    /// this (`slots * slot_tokens` tokens of KV per shard) so every
    /// ablation arm runs equal simulated hardware.
    pub slots: usize,
    /// Worst-case tokens one sequence may occupy (prompt + generation
    /// budget + [`KV_MARGIN`]).
    pub slot_tokens: usize,
    /// Paged mode still bounds concurrent residents (thrash guard);
    /// slot mode is bounded by `slots` regardless.
    pub max_members: usize,
    /// Fused group cap per round (`max_fuse`).
    pub group_cap: usize,
    /// Summed window-width budget per fused round (`fuse_tokens`).
    pub token_budget: usize,
    /// Per-member decode config; `seq_id` is overridden with the
    /// request id so streams are placement-independent.
    pub oracle: OracleConfig,
}

impl TierConfig {
    /// Defaults mirroring one `OracleFleet` coordinator per shard.
    pub fn new(oracle: OracleConfig) -> TierConfig {
        TierConfig {
            shards: 1,
            placement: Placement::LeastLoaded,
            paged: true,
            page_tokens: 16,
            slots: 8,
            slot_tokens: 256,
            max_members: 32,
            group_cap: 8,
            token_budget: 64,
            oracle,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            bail!("tier needs at least one shard");
        }
        if self.slots == 0 || self.slot_tokens == 0 {
            bail!("tier needs slots >= 1 and slot_tokens >= 1");
        }
        if self.page_tokens == 0 || self.page_tokens > self.slot_tokens {
            bail!(
                "page_tokens must be in [1, slot_tokens={}], got {}",
                self.slot_tokens,
                self.page_tokens
            );
        }
        if self.slot_tokens <= KV_MARGIN {
            bail!("slot_tokens must exceed the {KV_MARGIN}-token KV margin");
        }
        if self.max_members == 0 || self.group_cap == 0 || self.token_budget == 0 {
            bail!("max_members, group_cap and token_budget must be >= 1");
        }
        self.oracle.validate_hops()?;
        Ok(())
    }
}

/// One sequence resident on (or preempted from) a shard.
struct Member {
    id: u64,
    dec: OracleChainDecoder,
    arrival_ns: Nanos,
    prompt_len: usize,
    /// Clamped generation target (see [`KV_MARGIN`]).
    target: usize,
    /// Absolute sim time of the first committed decode round (0 = none).
    first_commit: Nanos,
    /// Paged-KV handle (`usize::MAX` in slot mode).
    kv: usize,
    /// True while preempted: pages evicted, host state intact.
    evicted: bool,
    /// Eviction order stamp — readmission is FIFO over these.
    evict_stamp: u64,
}

impl Member {
    fn done(&self) -> bool {
        self.dec.committed.len() - self.prompt_len >= self.target
    }
}

/// A finished sequence, handed from shard to tier at retirement.
pub struct Retired {
    pub id: u64,
    pub arrival_ns: Nanos,
    pub first_commit: Nanos,
    pub finish: Nanos,
    pub generated: Vec<i32>,
}

/// Per-shard counters for the fleet table and `BENCH_shard.json`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardRow {
    pub placed: u64,
    pub admitted: u64,
    pub retired: u64,
    pub preempted: u64,
    pub readmits: u64,
    pub faults: u64,
    pub pages_total: usize,
    pub pages_hwm: usize,
    pub peak_members: usize,
    pub peak_queue: usize,
    pub tokens: u64,
    pub group_rounds: u64,
    pub member_rounds: u64,
    pub sync_rounds: u64,
    pub comm_ns: Nanos,
    pub finish_ns: Nanos,
}

/// One coordinator shard: a pipeline replica + its KV capacity + the
/// fused-group round loop over its resident sequences.
pub struct Shard {
    pub sim: PipelineSim,
    cfg: TierConfig,
    members: Vec<Member>,
    queue: VecDeque<Request>,
    pool: Option<PagedKvPool>,
    slots_free: usize,
    per_stage: Vec<Nanos>,
    /// Sim time the most recent capacity release happened (admissions
    /// blocked on capacity start here, not at their arrival).
    cap_free_at: Nanos,
    next_stamp: u64,
    // Reusable round-loop buffers (zero-alloc steady state).
    pending: Vec<usize>,
    group: Vec<usize>,
    kept: Vec<usize>,
    kept_kv: Vec<usize>,
    group_kv: Vec<usize>,
    widths: Vec<usize>,
    gwidths: Vec<usize>,
    preps: Vec<(usize, OraclePrep, Nanos)>,
    round_buf: OracleRound,
    stats: AcceptanceStats,
    row: ShardRow,
}

impl Shard {
    /// Build shard `idx` of a tier (per-shard sim seed; identical
    /// topology and KV capacity across shards).
    pub fn new(cfg: &TierConfig, idx: usize) -> Result<Shard> {
        cfg.validate()?;
        let topo = cfg.oracle.topology();
        let sim_seed = cfg.oracle.seed ^ 0xF7 ^ (idx as u64).wrapping_mul(0x9E37);
        let sim = PipelineSim::new(topo, sim_seed);
        let per_stage =
            vec![cfg.oracle.per_token_pass_ns / cfg.oracle.nodes as Nanos; cfg.oracle.nodes];
        let pool = if cfg.paged {
            let pages_per_slot = cfg.slot_tokens.div_ceil(cfg.page_tokens);
            Some(PagedKvPool::new(cfg.slots * pages_per_slot, cfg.page_tokens))
        } else {
            None
        };
        Ok(Shard {
            sim,
            slots_free: cfg.slots,
            cfg: cfg.clone(),
            members: Vec::new(),
            queue: VecDeque::new(),
            pool,
            per_stage,
            cap_free_at: 0,
            next_stamp: 0,
            pending: Vec::new(),
            group: Vec::new(),
            kept: Vec::new(),
            kept_kv: Vec::new(),
            group_kv: Vec::new(),
            widths: Vec::new(),
            gwidths: Vec::new(),
            preps: Vec::new(),
            round_buf: OracleRound::default(),
            stats: AcceptanceStats::default(),
            row: ShardRow::default(),
        })
    }

    /// Queue a placed request (FIFO admission; no head-of-line bypass,
    /// so backpressure is deterministic).
    pub fn enqueue(&mut self, req: Request) {
        self.queue.push_back(req);
        self.row.placed += 1;
        self.row.peak_queue = self.row.peak_queue.max(self.queue.len());
    }

    /// Live sequences this shard owns (resident + preempted + queued).
    pub fn load(&self) -> usize {
        self.members.iter().filter(|m| !m.done()).count() + self.queue.len()
    }

    /// Earliest time a resident unfinished member could start a round.
    pub fn next_ready(&self) -> Option<Nanos> {
        self.members
            .iter()
            .filter(|m| !m.evicted && !m.done())
            .map(|m| m.dec.finish_time())
            .min()
    }

    /// Any sequence still owed tokens (resident, preempted, or queued)?
    pub fn draining(&self) -> bool {
        !self.queue.is_empty() || self.members.iter().any(|m| !m.done())
    }

    fn clamp_target(&self, prompt_len: usize, want: usize) -> usize {
        let cap = self.cfg.slot_tokens.saturating_sub(prompt_len + KV_MARGIN);
        want.min(cap).max(1)
    }

    /// Readmit preempted members (FIFO by eviction stamp) and admit
    /// queued requests whose arrival is <= `t`, while capacity allows.
    /// Readmission charges a recompute pass over the committed prefix;
    /// admission charges a prefill pass over the prompt. Both paths
    /// allocate — they are outside the round loop's zero-alloc budget
    /// by design.
    pub fn pump(&mut self, t: Nanos) {
        // Readmits take priority over new admissions (they already hold
        // a router placement and their latency clock is running).
        loop {
            let mut pick: Option<(u64, usize)> = None;
            for (i, m) in self.members.iter().enumerate() {
                if m.evicted && !m.done() {
                    let key = (m.evict_stamp, i);
                    if pick.map_or(true, |p| (p.0, p.1) > key) {
                        pick = Some(key);
                    }
                }
            }
            let Some((_, i)) = pick else { break };
            let committed = self.members[i].dec.committed.len();
            let Some(pool) = self.pool.as_mut() else { break };
            if !pool.readmit(self.members[i].kv, committed) {
                break;
            }
            self.row.readmits += 1;
            // Recompute: replay the committed prefix through the
            // pipeline (one pass of width = prefix), then decode from
            // its finish. Bit-identical KV falls out of purity: oracle
            // rows are functions of the prefix, draws are
            // position-keyed.
            let start = self.members[i].dec.finish_time().max(self.cap_free_at);
            let timing = self.sim.window_pass(
                start,
                committed,
                &self.per_stage,
                self.cfg.oracle.d_model * 4,
                self.cfg.oracle.vocab * 4,
            );
            self.members[i].dec.schedule_at(timing.finish);
            self.members[i].evicted = false;
        }
        // FIFO admissions.
        while let Some(front) = self.queue.front() {
            if front.arrival_ns > t {
                break;
            }
            let prompt_len = front.prompt.len().max(1);
            let has_capacity = match self.pool.as_ref() {
                Some(pool) => {
                    self.members.iter().filter(|m| !m.done()).count() < self.cfg.max_members
                        && pool.can_admit(prompt_len)
                }
                None => self.slots_free > 0,
            };
            if !has_capacity {
                break;
            }
            let req = match self.queue.pop_front() {
                Some(r) => r,
                None => break,
            };
            if self.admit(req, t).is_err() {
                break;
            }
        }
    }

    fn admit(&mut self, req: Request, _t: Nanos) -> Result<()> {
        let prompt: &[i32] = if req.prompt.is_empty() { &[1] } else { &req.prompt };
        let prompt_len = prompt.len();
        let target = self.clamp_target(prompt_len, req.max_new_tokens);
        let horizon = prompt_len + target + KV_MARGIN;
        let kv = match self.pool.as_mut() {
            Some(pool) => match pool.admit(req.id, prompt_len, horizon) {
                Some(h) => h,
                None => bail!("admission raced capacity away"),
            },
            None => {
                self.slots_free -= 1;
                usize::MAX
            }
        };
        let cfg = OracleConfig { seq_id: req.id, ..self.cfg.oracle.clone() };
        let mut dec = OracleChainDecoder::new(cfg, prompt)?;
        // Prefill: one pipeline pass over the prompt, starting when the
        // request arrived or when capacity last freed, whichever is
        // later. TTFT = queueing + prefill + first decode round.
        let start = req.arrival_ns.max(self.cap_free_at);
        let timing = self.sim.window_pass(
            start,
            prompt_len,
            &self.per_stage,
            self.cfg.oracle.d_model * 4,
            self.cfg.oracle.vocab * 4,
        );
        dec.schedule_at(timing.finish);
        self.members.push(Member {
            id: req.id,
            dec,
            arrival_ns: req.arrival_ns,
            prompt_len,
            target,
            first_commit: 0,
            kv,
            evicted: false,
            evict_stamp: 0,
        });
        self.row.admitted += 1;
        self.row.peak_members = self.row.peak_members.max(self.members.len());
        // Keep round-loop buffers sized for the member count so the
        // steady state never grows them mid-round.
        let n = self.members.len();
        self.pending.reserve(n);
        self.group.reserve(n);
        self.kept.reserve(n);
        self.kept_kv.reserve(n + 1);
        self.group_kv.reserve(n);
        self.widths.reserve(n);
        self.gwidths.reserve(n);
        self.preps.reserve(n);
        Ok(())
    }

    /// Ensure `m`'s page table covers its next verify window, evicting
    /// LRU residents on faults. Victims are preferred OUTSIDE the whole
    /// packed group (`self.group_kv` — evicting a group-mate costs a
    /// recompute next round); when only group-mates remain, the
    /// fallback excludes just `self.kept_kv` ("kept so far + the member
    /// being ensured"), so a grower can never evict itself or an
    /// already-kept peer — the head's exclusion list is then exactly
    /// itself, which is the progress guarantee. Returns false if the
    /// growth cannot be satisfied this round (the member is deferred).
    /// No-fault calls are allocation-free.
    fn ensure_kv(&mut self, m: usize, width: usize) -> bool {
        if self.pool.is_none() {
            return true;
        }
        let need = self.members[m].dec.committed.len() + width;
        let handle = self.members[m].kv;
        loop {
            let Some(pool) = self.pool.as_mut() else { return true };
            match pool.grow(handle, need) {
                Grow::Held | Grow::Allocated(_) => {
                    pool.touch(handle);
                    return true;
                }
                Grow::Fault => {
                    self.row.faults += 1;
                    let vh = match pool.lru_resident_except(&self.group_kv) {
                        Some(h) => h,
                        None => match pool.lru_resident_except(&self.kept_kv) {
                            Some(h) => h,
                            None => return false,
                        },
                    };
                    pool.evict(vh);
                    self.row.preempted += 1;
                    self.next_stamp += 1;
                    let stamp = self.next_stamp;
                    for mem in self.members.iter_mut() {
                        if mem.kv == vh {
                            mem.evicted = true;
                            mem.evict_stamp = stamp;
                        }
                    }
                }
            }
        }
    }

    /// One fused group round over the resident unfinished members
    /// (earliest-ready-first within `group_cap` / `token_budget`, page
    /// growth with LRU preemption, ONE group pass, per-member finish).
    /// Returns false (and does nothing) when no member can run.
    pub fn serve_round(&mut self) -> bool {
        let mut pending = std::mem::take(&mut self.pending);
        pending.clear();
        for i in 0..self.members.len() {
            if !self.members[i].evicted && !self.members[i].done() {
                pending.push(i);
            }
        }
        if pending.is_empty() {
            self.pending = pending;
            return false;
        }
        pending.sort_unstable_by_key(|&i| (self.members[i].dec.finish_time(), self.members[i].id));
        let mut widths = std::mem::take(&mut self.widths);
        widths.clear();
        widths.resize(self.members.len(), 0);
        for &i in &pending {
            widths[i] = self.members[i].dec.next_window_width();
        }
        let mut group = std::mem::take(&mut self.group);
        let (cap, budget) = (self.cfg.group_cap, self.cfg.token_budget);
        pack_earliest_ready(&pending, &widths, cap, budget, &mut group);
        // Page growth before any prep: members whose growth faults with
        // no victim left are deferred to a later round; the head always
        // survives (it may evict any other resident, and a single
        // sequence always fits the pool by slot_tokens sizing).
        let mut kept = std::mem::take(&mut self.kept);
        kept.clear();
        self.kept_kv.clear();
        self.group_kv.clear();
        for &m in &group {
            self.group_kv.push(self.members[m].kv);
        }
        for &m in &group {
            // An earlier grower may have evicted this very member as a
            // last-resort victim (see ensure_kv); its pages are gone,
            // so it defers to readmission instead of running.
            if self.members[m].evicted {
                continue;
            }
            // A grower may never evict itself or an already-kept peer;
            // the head's fallback exclusion list is then exactly
            // itself, so it can evict any other resident and always
            // succeeds (one sequence always fits the pool by
            // slot_tokens sizing).
            self.kept_kv.push(self.members[m].kv);
            if self.ensure_kv(m, widths[m]) {
                kept.push(m);
            } else {
                self.kept_kv.pop();
            }
        }
        if kept.is_empty() {
            self.pending = pending;
            self.widths = widths;
            self.group = group;
            self.kept = kept;
            return false;
        }
        // Draft phases serialized on the shared leader, then ONE fused
        // pass — the OracleFleet round shape on this shard's pipeline.
        let mut preps = std::mem::take(&mut self.preps);
        preps.clear();
        for &m in &kept {
            let ready = self.members[m].dec.finish_time();
            let prep = self.members[m].dec.prep_round();
            self.sim.trace_key(TraceKey::new(
                self.members[m].dec.cfg.seq_id as u32,
                self.members[m].dec.round_index(),
                (self.sim.stats.sync_rounds + 1) as u32,
            ));
            let draft_done = if prep.draft_ns == 0 {
                ready
            } else {
                self.sim.local_work(ready, prep.draft_ns)
            };
            preps.push((m, prep, draft_done));
        }
        let start = preps.iter().map(|p| p.2).max().unwrap_or(0);
        let mut gwidths = std::mem::take(&mut self.gwidths);
        gwidths.clear();
        gwidths.extend(preps.iter().map(|(_, p, _)| p.gamma + 1));
        let timing = self.sim.group_pass(
            start,
            &gwidths,
            &self.per_stage,
            self.cfg.oracle.d_model * 4,
            self.cfg.oracle.vocab * 4,
        );
        self.row.group_rounds += 1;
        self.row.member_rounds += preps.len() as u64;
        let fuse_width = gwidths.len();
        let mut round_buf = std::mem::take(&mut self.round_buf);
        for (m, prep, _) in preps.drain(..) {
            self.members[m].dec.finish_round_into(&mut self.sim, prep, timing, &mut round_buf);
            if self.members[m].first_commit == 0 {
                self.members[m].first_commit = round_buf.finish;
            }
            self.stats.record(round_buf.record(fuse_width));
        }
        self.round_buf = round_buf;
        self.pending = pending;
        self.widths = widths;
        self.group = group;
        self.kept = kept;
        self.preps = preps;
        self.gwidths = gwidths;
        true
    }

    /// Move finished members out (capacity released at each member's
    /// finish time, in ascending finish order so admissions unblock
    /// deterministically).
    pub fn take_retired(&mut self, out: &mut Vec<Retired>) {
        loop {
            let mut pick: Option<(Nanos, u64, usize)> = None;
            for (i, m) in self.members.iter().enumerate() {
                if m.done() {
                    let key = (m.dec.finish_time(), m.id, i);
                    if pick.map_or(true, |p| (p.0, p.1, p.2) > key) {
                        pick = Some(key);
                    }
                }
            }
            let Some((finish, _, i)) = pick else { break };
            let m = self.members.swap_remove(i);
            match self.pool.as_mut() {
                Some(pool) => pool.release(m.kv),
                None => self.slots_free += 1,
            }
            self.cap_free_at = self.cap_free_at.max(finish);
            self.row.retired += 1;
            self.row.tokens += (m.dec.committed.len() - m.prompt_len) as u64;
            self.row.finish_ns = self.row.finish_ns.max(finish);
            out.push(Retired {
                id: m.id,
                arrival_ns: m.arrival_ns,
                first_commit: m.first_commit,
                finish,
                generated: m.dec.committed[m.prompt_len..].to_vec(),
            });
        }
    }

    /// Pre-reserve every member's round buffers (alloc-budget warmup).
    pub fn warm_capacity(&mut self, extra_tokens_per_seq: usize) {
        for m in self.members.iter_mut() {
            m.dec.warm_capacity(extra_tokens_per_seq);
        }
        self.round_buf.committed.reserve(64);
    }

    /// Snapshot of this shard's counters (pool + sim stats folded in).
    pub fn row(&self) -> ShardRow {
        let mut row = self.row;
        if let Some(pool) = self.pool.as_ref() {
            row.pages_total = pool.total_pages();
            row.pages_hwm = pool.stats.hwm_pages;
        }
        row.sync_rounds = self.sim.stats.sync_rounds;
        row.comm_ns = self.sim.stats.comm_ns;
        row
    }

    /// Acceptance/overlap stats across every member round so far.
    pub fn accept_stats(&self) -> &AcceptanceStats {
        &self.stats
    }
}

/// Aggregate result of a tier run.
#[derive(Debug, Clone)]
pub struct TierReport {
    pub requests: u64,
    pub tokens: u64,
    /// Makespan: last retirement (ns since the first arrival epoch).
    pub finish_ns: Nanos,
    pub ttft: Histogram,
    pub latency: Histogram,
    pub accept: AcceptanceStats,
    pub shards: Vec<ShardRow>,
}

impl TierReport {
    /// Sustained generated-token throughput over the makespan.
    pub fn tokens_per_s(&self) -> f64 {
        if self.finish_ns == 0 {
            return 0.0;
        }
        self.tokens as f64 / (self.finish_ns as f64 / 1e9)
    }
}

/// The serving tier: placement over M shards + per-shard round loops,
/// advanced in global arrival order.
pub struct ShardTier {
    pub cfg: TierConfig,
    shards: Vec<Shard>,
    router: Router,
    ttft: Histogram,
    latency: Histogram,
    /// Generated tokens per request id — the differential tests compare
    /// these across placements, page sizes, and evict/readmit cycles.
    generated: BTreeMap<u64, Vec<i32>>,
    retired: Vec<Retired>,
    finish_ns: Nanos,
    requests: u64,
}

impl ShardTier {
    pub fn new(cfg: TierConfig) -> Result<ShardTier> {
        cfg.validate()?;
        let mut shards = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            shards.push(Shard::new(&cfg, i)?);
        }
        let router = Router::new(cfg.shards, RoutePolicy::LeastLoaded);
        Ok(ShardTier {
            cfg,
            shards,
            router,
            ttft: Histogram::latency(),
            latency: Histogram::latency(),
            generated: BTreeMap::new(),
            retired: Vec::new(),
            finish_ns: 0,
            requests: 0,
        })
    }

    /// Serve `requests` (must be in arrival order) to completion.
    pub fn run(&mut self, requests: &[Request]) -> Result<TierReport> {
        for w in requests.windows(2) {
            if w[1].arrival_ns < w[0].arrival_ns {
                bail!("requests must be sorted by arrival time");
            }
        }
        for req in requests {
            let t = req.arrival_ns;
            for s in 0..self.shards.len() {
                self.advance(s, t);
            }
            let weight = (req.prompt.len() + req.max_new_tokens) as u64;
            let shard = match self.cfg.placement {
                Placement::Hash => (req.id % self.cfg.shards as u64) as usize,
                Placement::LeastLoaded => self.router.place(req.id, weight),
            };
            self.shards[shard].enqueue(req.clone());
            self.requests += 1;
        }
        // Drain: shards are independent after placement, so one full
        // pass per shard completes everything it owns.
        for s in 0..self.shards.len() {
            self.advance(s, Nanos::MAX);
            debug_assert!(!self.shards[s].draining(), "shard {s} failed to drain");
        }
        let mut accept = AcceptanceStats::default();
        let mut tokens = 0u64;
        let mut rows = Vec::with_capacity(self.shards.len());
        for sh in &self.shards {
            accept.merge(sh.accept_stats());
            let row = sh.row();
            tokens += row.tokens;
            rows.push(row);
        }
        Ok(TierReport {
            requests: self.requests,
            tokens,
            finish_ns: self.finish_ns,
            ttft: self.ttft.clone(),
            latency: self.latency.clone(),
            accept,
            shards: rows,
        })
    }

    /// Generated tokens per request id, recorded at retirement.
    pub fn generated(&self) -> &BTreeMap<u64, Vec<i32>> {
        &self.generated
    }

    /// Process shard `s` up to time `t`: admissions/readmits, then
    /// rounds whose earliest-ready member is due, retiring after each.
    fn advance(&mut self, s: usize, t: Nanos) {
        loop {
            self.shards[s].pump(t);
            let Some(next) = self.shards[s].next_ready() else { break };
            if next > t {
                break;
            }
            if !self.shards[s].serve_round() {
                break;
            }
            self.retire(s);
        }
    }

    fn retire(&mut self, s: usize) {
        let mut retired = std::mem::take(&mut self.retired);
        self.shards[s].take_retired(&mut retired);
        for r in retired.drain(..) {
            self.ttft.record(r.first_commit.saturating_sub(r.arrival_ns));
            self.latency.record(r.finish.saturating_sub(r.arrival_ns));
            self.finish_ns = self.finish_ns.max(r.finish);
            if self.cfg.placement == Placement::LeastLoaded {
                self.router.finish(r.id);
            }
            self.generated.insert(r.id, r.generated);
        }
        self.retired = retired;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{dataset, WorkloadGen};

    fn small_oracle(seed: u64) -> OracleConfig {
        OracleConfig { seed, nodes: 3, link_ms: 2.0, vocab: 32, ..Default::default() }
    }

    fn tier_cfg(seed: u64) -> TierConfig {
        let mut cfg = TierConfig::new(small_oracle(seed));
        cfg.slots = 4;
        cfg.slot_tokens = 96;
        cfg.group_cap = 4;
        cfg.token_budget = 40;
        cfg
    }

    fn requests(n: usize, rate: f64, seed: u64) -> Vec<Request> {
        let profile = dataset("humaneval").expect("profile");
        let mut gen = WorkloadGen::new(profile, 32, seed);
        let mut reqs = gen.open_loop(n, rate, 2.0, 4);
        for r in reqs.iter_mut() {
            r.max_new_tokens = r.max_new_tokens.min(24);
            r.prompt.truncate(12);
        }
        reqs
    }

    fn run_tier(mut cfg: TierConfig, reqs: &[Request]) -> (TierReport, BTreeMap<u64, Vec<i32>>) {
        cfg.oracle.seq_id = 0;
        let mut tier = ShardTier::new(cfg).expect("tier");
        let report = tier.run(reqs).expect("run");
        (report, tier.generated().clone())
    }

    #[test]
    fn tier_serves_every_request_exactly_once() {
        let reqs = requests(12, 400.0, 7);
        let (report, gen) = run_tier(tier_cfg(7), &reqs);
        assert_eq!(report.requests, 12);
        assert_eq!(gen.len(), 12);
        assert_eq!(report.ttft.count(), 12);
        assert_eq!(report.latency.count(), 12);
        assert!(report.tokens > 0);
        assert!(report.finish_ns > 0);
        for r in &reqs {
            let toks = gen.get(&r.id).expect("every id served");
            assert!(!toks.is_empty());
        }
    }

    #[test]
    fn streams_are_placement_independent() {
        // Every draw is keyed by (seed, request id, position): hash
        // partitioning, least-loaded sharding, and a single coordinator
        // must commit byte-identical streams per request.
        let reqs = requests(10, 600.0, 11);
        let mut single = tier_cfg(11);
        single.shards = 1;
        let (_, g1) = run_tier(single, &reqs);
        let mut hash = tier_cfg(11);
        hash.shards = 3;
        hash.placement = Placement::Hash;
        let (_, g2) = run_tier(hash, &reqs);
        let mut ll = tier_cfg(11);
        ll.shards = 3;
        ll.placement = Placement::LeastLoaded;
        let (_, g3) = run_tier(ll, &reqs);
        assert_eq!(g1, g2, "hash partition must not change streams");
        assert_eq!(g1, g3, "least-loaded sharding must not change streams");
    }

    #[test]
    fn streams_are_page_size_invariant_under_preemption_pressure() {
        // A pool tight enough to preempt constantly must still commit
        // identical streams across page sizes (timing changes, tokens
        // never do).
        let reqs = requests(10, 2000.0, 13);
        let mut baseline = tier_cfg(13);
        baseline.paged = false;
        let (_, gs) = run_tier(baseline, &reqs);
        let mut evictions_seen = 0u64;
        for page in [1usize, 16, 64] {
            let mut cfg = tier_cfg(13);
            cfg.slots = 2; // tight: force faults + evictions
            cfg.page_tokens = page;
            let (report, gp) = run_tier(cfg, &reqs);
            evictions_seen += report.shards.iter().map(|r| r.preempted).sum::<u64>();
            assert_eq!(gs, gp, "page size {page} changed committed streams");
        }
        assert!(evictions_seen > 0, "pressure config must actually preempt");
    }

    #[test]
    fn paged_admission_beats_slot_admission_on_concurrency() {
        // Same KV bytes: working-set admission must reach a higher peak
        // of concurrently admitted members than worst-case slots.
        let reqs = requests(16, 4000.0, 17);
        let mut slot = tier_cfg(17);
        slot.paged = false;
        let (rs, _) = run_tier(slot, &reqs);
        let mut paged = tier_cfg(17);
        paged.paged = true;
        let (rp, _) = run_tier(paged, &reqs);
        let slot_peak: usize = rs.shards.iter().map(|r| r.peak_members).max().unwrap_or(0);
        let paged_peak: usize = rp.shards.iter().map(|r| r.peak_members).max().unwrap_or(0);
        assert!(
            paged_peak > slot_peak,
            "paged peak {paged_peak} must exceed slot peak {slot_peak}"
        );
        assert!(slot_peak <= 4, "slot mode cannot exceed its slot count");
    }

    #[test]
    fn tier_validates_its_knobs() {
        let mut cfg = tier_cfg(1);
        cfg.shards = 0;
        assert!(ShardTier::new(cfg).is_err());
        let mut cfg = tier_cfg(1);
        cfg.page_tokens = 0;
        assert!(ShardTier::new(cfg).is_err());
        let mut cfg = tier_cfg(1);
        cfg.page_tokens = cfg.slot_tokens + 1;
        assert!(ShardTier::new(cfg).is_err());
    }
}
