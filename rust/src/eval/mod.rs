//! Accuracy evaluation (the paper's per-dataset accuracy columns).
//!
//! With synthetic weights the meaningful notion of "accuracy" is fidelity
//! to the target model's own behavior (DESIGN.md §5): strict speculative
//! decoding is provably lossless w.r.t. the target distribution, and
//! adaptive relaxation trades exactly that fidelity for speed. We measure:
//!
//! * greedy mode: exact token agreement with the target-greedy reference
//!   continuation (deterministic);
//! * sampling mode: per-position agreement with the target-greedy
//!   reference ("answer tokens"), which for the base system reflects the
//!   temperature-entropy of the task and for DSD additionally reflects
//!   any τ-induced drift — the same comparison Table 1 makes between
//!   "Base Acc" and DSD accuracy at t=1.0.

/// Fraction of positions agreeing with the reference continuation.
pub fn token_agreement(output: &[i32], reference: &[i32]) -> f64 {
    if reference.is_empty() {
        return 0.0;
    }
    let n = output.len().min(reference.len());
    if n == 0 {
        return 0.0;
    }
    let hits = output[..n].iter().zip(&reference[..n]).filter(|(a, b)| a == b).count();
    hits as f64 / n as f64
}

/// Exact-match of the final `answer_len` tokens (GSM8K-style EM proxy).
pub fn answer_exact_match(output: &[i32], reference: &[i32], answer_len: usize) -> bool {
    if output.len() < answer_len || reference.len() < answer_len {
        return false;
    }
    output[output.len() - answer_len..] == reference[reference.len() - answer_len..]
}

/// Longest-common-subsequence ratio (ROUGE-L proxy for the CNN/DM task).
pub fn lcs_ratio(output: &[i32], reference: &[i32]) -> f64 {
    if output.is_empty() || reference.is_empty() {
        return 0.0;
    }
    let n = output.len();
    let m = reference.len();
    let mut dp = vec![0usize; (n + 1) * (m + 1)];
    for i in 1..=n {
        for j in 1..=m {
            dp[i * (m + 1) + j] = if output[i - 1] == reference[j - 1] {
                dp[(i - 1) * (m + 1) + (j - 1)] + 1
            } else {
                dp[(i - 1) * (m + 1) + j].max(dp[i * (m + 1) + (j - 1)])
            };
        }
    }
    dp[n * (m + 1) + m] as f64 / m as f64
}

/// Aggregate accuracy over a run, dataset-metric-aware.
#[derive(Debug, Clone, Default)]
pub struct AccuracyAggregator {
    sum_agreement: f64,
    sum_lcs: f64,
    exact_matches: u64,
    n: u64,
}

impl AccuracyAggregator {
    pub fn add(&mut self, output: &[i32], reference: &[i32]) {
        self.sum_agreement += token_agreement(output, reference);
        self.sum_lcs += lcs_ratio(output, reference);
        if answer_exact_match(output, reference, 8.min(reference.len())) {
            self.exact_matches += 1;
        }
        self.n += 1;
    }

    pub fn mean_agreement(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_agreement / self.n as f64
        }
    }

    pub fn mean_lcs(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_lcs / self.n as f64
        }
    }

    pub fn exact_match_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.exact_matches as f64 / self.n as f64
        }
    }

    /// The headline accuracy for a dataset's metric name.
    pub fn for_metric(&self, metric: &str) -> f64 {
        match metric {
            "exact-match" => self.exact_match_rate(),
            "rouge-l" => self.mean_lcs(),
            _ => self.mean_agreement(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agreement_counts_positions() {
        assert!((token_agreement(&[1, 2, 3, 4], &[1, 2, 9, 4]) - 0.75).abs() < 1e-9);
        assert_eq!(token_agreement(&[], &[1]), 0.0);
        assert!((token_agreement(&[1, 2], &[1, 2, 3, 4]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn exact_match_tail() {
        assert!(answer_exact_match(&[9, 9, 1, 2, 3], &[0, 1, 2, 3], 3));
        assert!(!answer_exact_match(&[9, 9, 1, 2, 4], &[0, 1, 2, 3], 3));
        assert!(!answer_exact_match(&[1], &[1, 2], 2));
    }

    #[test]
    fn lcs_properties() {
        assert!((lcs_ratio(&[1, 2, 3], &[1, 2, 3]) - 1.0).abs() < 1e-9);
        assert_eq!(lcs_ratio(&[4, 5], &[1, 2, 3]), 0.0);
        let r = lcs_ratio(&[1, 9, 2, 9, 3], &[1, 2, 3]);
        assert!((r - 1.0).abs() < 1e-9); // subsequence preserved
    }

    #[test]
    fn aggregator_metrics() {
        let mut a = AccuracyAggregator::default();
        a.add(&[1, 2, 3, 4, 5, 6, 7, 8], &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.add(&[1, 2, 3, 4, 5, 6, 7, 0], &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert!((a.mean_agreement() - (1.0 + 0.875) / 2.0).abs() < 1e-9);
        assert!((a.exact_match_rate() - 0.5).abs() < 1e-9);
        assert!(a.for_metric("exact-match") < a.for_metric("pass@1"));
    }
}
