//! Deployment configuration: a layered system (defaults ← TOML-lite file
//! ← CLI overrides) describing the cluster, model sharding, decode
//! policy, and workload — the launcher's single source of truth.
//!
//! The file format is a flat `key = value` subset of TOML (sections are
//! allowed and become `section.key`); see `examples/deploy.toml` written
//! by `dsd init-config`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::{LinkModel, Topology};
use crate::control::ControllerKind;
use crate::coordinator::router::Placement;
use crate::spec::{DecodeConfig, DraftShape, Policy};
use crate::util::cli::{parse_on_off, Args};

/// Everything needed to launch a deployment.
#[derive(Debug, Clone)]
pub struct DeployConfig {
    /// Artifact directory (manifest.json, weights.bin, *.hlo.txt).
    pub artifacts_dir: String,
    /// Pipeline stages = nodes.
    pub n_nodes: usize,
    /// Per-link one-way latency, milliseconds (the paper's t1). When
    /// `link_ms_hops` is set this holds the mean hop latency (kept for
    /// reports and the analytic scalar model).
    pub link_ms: f64,
    /// Per-hop one-way latencies, milliseconds: `link_ms = "a,b,c"`
    /// gives one value per *forward* pipeline hop (N−1 entries for N
    /// nodes; the return hop reuses the last value — see
    /// `Topology::chain_from_forward`). Empty = uniform at `link_ms`.
    pub link_ms_hops: Vec<f64>,
    /// Link bandwidth, Gbps (0 = infinite).
    pub link_gbps: f64,
    /// Link jitter fraction.
    pub jitter: f64,
    /// Draft variant name (agreement ladder); empty = per-dataset default.
    pub draft_variant: String,
    /// Decode settings.
    pub decode: DecodeConfig,
    /// Max concurrent sequences (KV slot pool size).
    pub max_batch: usize,
    /// Fused group rounds: pack concurrent sequences' verify windows
    /// into one pipeline pass (one cross-node sync per group). `off`
    /// runs the legacy per-sequence rounds. At a fixed config, token
    /// streams are byte-identical across realized group compositions;
    /// toggling `fuse` itself also changes nothing for the static
    /// controller (the default), but is a pricing input for
    /// `cost-optimal` (like `link_ms`), which may then pick different γ.
    pub fuse: bool,
    /// Max sequences per fused group round (>= 1; 1 ≡ fuse off).
    pub max_fuse: usize,
    /// Token budget of one fused group pass: summed member window
    /// widths must fit (must cover the widest single window).
    pub fuse_tokens: usize,
    /// Workload dataset name.
    pub dataset: String,
    /// Number of requests.
    pub requests: usize,
    /// RNG seed.
    pub seed: u64,
    /// Straggler threshold: a link whose calibrated per-hop estimate
    /// exceeds `straggler_factor ×` the fleet median is flagged in the
    /// serve report (see `telemetry::FleetMetrics::straggler_links`).
    pub straggler_factor: f64,
    /// Online per-link calibration: re-price the controller's cost
    /// model each round from the telemetry EWMA hop estimates (off =
    /// the controller trusts the configured `link_ms` forever).
    pub calibrate: bool,
    /// Coordinator shards in the serving tier (each a full pipeline
    /// replica; 1 = the classic single coordinator).
    pub shards: usize,
    /// Request placement across shards (`least-loaded` through the
    /// id-keyed router, `hash` = static id partition).
    pub placement: Placement,
    /// Tokens per KV page for the paged admission pool (bounded by the
    /// per-sequence slot capacity, see [`DeployConfig::slot_tokens`]).
    pub kv_page_tokens: usize,
    /// Open-loop arrival rate, requests/second (0 = closed-loop: every
    /// request available at t=0, the pre-serving-tier behavior).
    pub arrival_rps: f64,
}

impl Default for DeployConfig {
    fn default() -> Self {
        DeployConfig {
            artifacts_dir: "artifacts".to_string(),
            n_nodes: 4,
            link_ms: 15.0,
            link_ms_hops: Vec::new(),
            link_gbps: 1.0,
            jitter: 0.0,
            draft_variant: String::new(),
            decode: DecodeConfig::default(),
            max_batch: 8,
            fuse: true,
            max_fuse: 4,
            fuse_tokens: 64,
            dataset: "humaneval".to_string(),
            requests: 8,
            seed: 20250710,
            straggler_factor: 3.0,
            calibrate: false,
            shards: 1,
            placement: Placement::LeastLoaded,
            kv_page_tokens: 16,
            arrival_rps: 0.0,
        }
    }
}

impl DeployConfig {
    /// Validate the whole deployment before launch — clear errors at
    /// config/CLI time instead of panics deep in the round loop.
    pub fn validate(&self) -> Result<()> {
        if self.n_nodes == 0 {
            bail!("n_nodes must be >= 1");
        }
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1 (KV slot pool size)");
        }
        if !self.link_ms.is_finite() || self.link_ms < 0.0 {
            bail!("link_ms must be a non-negative number, got {}", self.link_ms);
        }
        if !self.link_ms_hops.is_empty() {
            if self.link_ms_hops.len() != self.n_nodes.saturating_sub(1) {
                bail!(
                    "link_ms lists one value per forward hop: got {} values for \
                     n_nodes = {} (need {})",
                    self.link_ms_hops.len(),
                    self.n_nodes,
                    self.n_nodes.saturating_sub(1)
                );
            }
            for &ms in &self.link_ms_hops {
                if !ms.is_finite() || ms < 0.0 {
                    bail!("link_ms hop values must be non-negative numbers, got {ms}");
                }
            }
        }
        if !self.jitter.is_finite() || self.jitter < 0.0 {
            bail!("jitter must be a non-negative fraction, got {}", self.jitter);
        }
        if !self.straggler_factor.is_finite() || self.straggler_factor <= 1.0 {
            bail!(
                "straggler_factor must be > 1 (a link is flagged when its estimate \
                 exceeds factor x the fleet median), got {}",
                self.straggler_factor
            );
        }
        if self.max_fuse == 0 {
            bail!("max_fuse must be >= 1 (1 disables fusion; use fuse = off instead)");
        }
        // The budget bound applies where fusion can actually engage:
        // speculative chain decoding (AR and tree rounds run solo).
        if self.fuse
            && self.max_fuse > 1
            && self.decode.policy.is_speculative()
            && self.decode.shape.is_chain()
            && self.fuse_tokens < self.decode.max_window()
        {
            bail!(
                "fuse_tokens ({}) must be >= the widest verify window ({} = gamma + 1); \
                 raise fuse_tokens or lower gamma, or disable fusion with fuse = off",
                self.fuse_tokens,
                self.decode.max_window()
            );
        }
        if self.shards == 0 {
            bail!("shards must be >= 1 (1 is the classic single coordinator)");
        }
        if self.kv_page_tokens == 0 || self.kv_page_tokens > self.slot_tokens() {
            bail!(
                "kv_page_tokens must be in [1, {}] (the per-sequence slot capacity \
                 for dataset '{}' at max_new_tokens {}), got {}",
                self.slot_tokens(),
                self.dataset,
                self.decode.max_new_tokens,
                self.kv_page_tokens
            );
        }
        if !self.arrival_rps.is_finite() || self.arrival_rps < 0.0 {
            bail!("arrival_rps must be a non-negative rate, got {}", self.arrival_rps);
        }
        self.decode.validate()
    }

    /// Worst-case tokens one sequence can occupy in the serving tier's
    /// KV pool: longest dataset prompt + the generation budget + the
    /// speculation overshoot margin. Slot admission reserves exactly
    /// this; paged admission only bounds page sizes by it.
    pub fn slot_tokens(&self) -> usize {
        let prompt_hi = crate::workload::dataset(&self.dataset).map_or(64, |d| d.prompt_len.1);
        prompt_hi + self.decode.max_new_tokens + crate::coordinator::shard::KV_MARGIN
    }

    pub fn topology(&self) -> Topology {
        let bandwidth_bps = if self.link_gbps <= 0.0 {
            0
        } else {
            (self.link_gbps * 1e9 / 8.0) as u64
        };
        if self.link_ms_hops.is_empty() {
            let link = LinkModel {
                base_ns: (self.link_ms * 1e6) as u64,
                bandwidth_bps,
                jitter: self.jitter,
            };
            Topology::uniform(self.n_nodes, link)
        } else {
            let forward = self
                .link_ms_hops
                .iter()
                .map(|&ms| LinkModel {
                    base_ns: (ms * 1e6) as u64,
                    bandwidth_bps,
                    jitter: self.jitter,
                })
                .collect();
            Topology::chain_from_forward(forward)
        }
    }

    /// Parse a TOML-lite config file into key/value pairs and apply.
    pub fn load_file(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let kv = parse_toml_lite(&text)?;
        for (k, v) in &kv {
            self.set(k, v)
                .with_context(|| format!("config key '{k}' in {}", path.as_ref().display()))?;
        }
        Ok(())
    }

    /// Apply CLI overrides (--key value with dots, e.g. --decode.tau 0.3).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        for (k, v) in &args.options {
            // Unknown CLI keys that aren't config fields are left to the
            // caller (e.g. --config itself).
            if k == "config" {
                continue;
            }
            if let Err(e) = self.set(k, v) {
                // Tolerate options the config doesn't own (--out,
                // --sweep_nodes, ...), but surface bad *values* for keys
                // it does recognize — `--draft_shape tree:x3` must error
                // with the accepted forms, not silently run as chain —
                // and typos in dotted keys.
                let foreign = e.to_string().starts_with("unknown config key");
                if k.contains('.') || !foreign {
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Set one field by dotted name.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "n_nodes" | "nodes" => self.n_nodes = value.parse()?,
            "link_ms" => {
                // `--link_ms 5,40,5` is the per-hop spelling (one value
                // per forward hop); a scalar resets to uniform links.
                if value.contains(',') {
                    let hops: Vec<f64> = value
                        .split(',')
                        .map(|s| s.trim().parse::<f64>())
                        .collect::<std::result::Result<_, _>>()?;
                    self.link_ms = hops.iter().sum::<f64>() / hops.len().max(1) as f64;
                    self.link_ms_hops = hops;
                } else {
                    self.link_ms = value.parse()?;
                    self.link_ms_hops.clear();
                }
            }
            "link_gbps" => self.link_gbps = value.parse()?,
            "jitter" => self.jitter = value.parse()?,
            "draft_variant" | "draft" => self.draft_variant = value.to_string(),
            "max_batch" => self.max_batch = value.parse()?,
            "fuse" => {
                self.fuse = parse_on_off(value)
                    .map_err(|_| anyhow::anyhow!("fuse expects on|off, got '{value}'"))?
            }
            "max_fuse" => self.max_fuse = value.parse()?,
            "fuse_tokens" => self.fuse_tokens = value.parse()?,
            "dataset" => self.dataset = value.to_string(),
            "requests" => self.requests = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "straggler_factor" => self.straggler_factor = value.parse()?,
            "calibrate" => {
                self.calibrate = parse_on_off(value)
                    .map_err(|_| anyhow::anyhow!("calibrate expects on|off, got '{value}'"))?
            }
            "shards" => self.shards = value.parse()?,
            "placement" => self.placement = Placement::parse(value)?,
            "kv_page_tokens" => self.kv_page_tokens = value.parse()?,
            "arrival_rps" => self.arrival_rps = value.parse()?,
            "decode.policy" | "policy" => {
                self.decode.policy = match value {
                    "baseline" | "autoregressive" | "ar" => Policy::Autoregressive,
                    "eagle3" | "eagle" => Policy::Eagle3,
                    "dsd" | "adaptive" => Policy::Dsd,
                    other => bail!("unknown policy '{other}'"),
                }
            }
            "decode.gamma" | "gamma" => self.decode.gamma = value.parse()?,
            "decode.draft_shape" | "draft_shape" => {
                self.decode.shape = DraftShape::parse(value)?
            }
            "decode.temp" | "temp" => self.decode.temp = value.parse()?,
            "decode.tau" | "tau" => self.decode.tau = value.parse()?,
            "decode.lam1" | "lam1" => self.decode.lam1 = value.parse()?,
            "decode.lam2" | "lam2" => self.decode.lam2 = value.parse()?,
            "decode.lam3" | "lam3" => self.decode.lam3 = value.parse()?,
            "decode.max_new_tokens" | "max_new_tokens" => {
                self.decode.max_new_tokens = value.parse()?
            }
            "decode.overlap" | "overlap" => {
                self.decode.overlap = parse_on_off(value)
                    .map_err(|_| anyhow::anyhow!("overlap expects on|off, got '{value}'"))?
            }
            "decode.controller" | "controller" => {
                self.decode.controller = ControllerKind::parse(value)?
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Render as a config file (round-trips through load_file).
    pub fn to_toml(&self) -> String {
        // per-hop lists render quoted ("5,40,5") so parse_toml_lite
        // hands the comma list back to set() intact
        let link_ms_repr = if self.link_ms_hops.is_empty() {
            self.link_ms.to_string()
        } else {
            let list: Vec<String> = self.link_ms_hops.iter().map(f64::to_string).collect();
            format!("\"{}\"", list.join(","))
        };
        format!(
            "# DSD deployment config\n\
             artifacts_dir = \"{}\"\n\
             n_nodes = {}\n\
             link_ms = {}\n\
             link_gbps = {}\n\
             jitter = {}\n\
             draft_variant = \"{}\"\n\
             max_batch = {}\n\
             fuse = \"{}\"\n\
             max_fuse = {}\n\
             fuse_tokens = {}\n\
             dataset = \"{}\"\n\
             requests = {}\n\
             seed = {}\n\
             straggler_factor = {}\n\
             calibrate = \"{}\"\n\
             shards = {}\n\
             placement = \"{}\"\n\
             kv_page_tokens = {}\n\
             arrival_rps = {}\n\n\
             [decode]\n\
             policy = \"{}\"\n\
             gamma = {}\n\
             draft_shape = \"{}\"\n\
             temp = {}\n\
             tau = {}\n\
             lam1 = {}\n\
             lam2 = {}\n\
             lam3 = {}\n\
             max_new_tokens = {}\n\
             overlap = \"{}\"\n\
             controller = \"{}\"\n",
            self.artifacts_dir,
            self.n_nodes,
            link_ms_repr,
            self.link_gbps,
            self.jitter,
            self.draft_variant,
            self.max_batch,
            if self.fuse { "on" } else { "off" },
            self.max_fuse,
            self.fuse_tokens,
            self.dataset,
            self.requests,
            self.seed,
            self.straggler_factor,
            if self.calibrate { "on" } else { "off" },
            self.shards,
            self.placement.name(),
            self.kv_page_tokens,
            self.arrival_rps,
            self.decode.policy.name(),
            self.decode.gamma,
            self.decode.shape.name(),
            self.decode.temp,
            self.decode.tau,
            self.decode.lam1,
            self.decode.lam2,
            self.decode.lam3,
            self.decode.max_new_tokens,
            if self.decode.overlap { "on" } else { "off" },
            self.decode.controller.name(),
        )
    }
}

/// Parse the `key = value` / `[section]` subset of TOML.
pub fn parse_toml_lite(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(sec) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = sec.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = v.trim().trim_matches('"').to_string();
        out.insert(key, val);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_lite_sections_and_comments() {
        let kv = parse_toml_lite(
            "a = 1 # comment\n[decode]\n tau = 0.2\n# full comment\npolicy = \"dsd\"\n",
        )
        .unwrap();
        assert_eq!(kv["a"], "1");
        assert_eq!(kv["decode.tau"], "0.2");
        assert_eq!(kv["decode.policy"], "dsd");
    }

    #[test]
    fn config_roundtrip() {
        let mut cfg = DeployConfig::default();
        cfg.set("decode.tau", "0.35").unwrap();
        cfg.set("nodes", "8").unwrap();
        cfg.set("policy", "eagle3").unwrap();
        cfg.set("draft_shape", "tree:4x3").unwrap();
        cfg.set("overlap", "off").unwrap();
        cfg.set("controller", "cost-optimal").unwrap();
        cfg.set("fuse", "off").unwrap();
        cfg.set("max_fuse", "6").unwrap();
        cfg.set("fuse_tokens", "96").unwrap();
        let text = cfg.to_toml();
        let mut cfg2 = DeployConfig::default();
        let kv = parse_toml_lite(&text).unwrap();
        for (k, v) in &kv {
            cfg2.set(k, v).unwrap();
        }
        assert_eq!(cfg2.n_nodes, 8);
        assert!((cfg2.decode.tau - 0.35).abs() < 1e-6);
        assert_eq!(cfg2.decode.policy, Policy::Eagle3);
        assert_eq!(cfg2.decode.shape, cfg.decode.shape);
        assert!(!cfg2.decode.overlap);
        assert_eq!(cfg2.decode.controller, ControllerKind::CostOptimal);
        assert!(!cfg2.fuse);
        assert_eq!(cfg2.max_fuse, 6);
        assert_eq!(cfg2.fuse_tokens, 96);
    }

    #[test]
    fn fuse_knobs_defaults_and_validation() {
        let cfg = DeployConfig::default();
        assert!(cfg.fuse, "fusion defaults on");
        assert_eq!(cfg.max_fuse, 4);
        assert!(cfg.fuse_tokens >= cfg.decode.max_window());
        assert!(cfg.validate().is_ok());

        // max_fuse = 0 is nonsense even with fuse off
        let mut cfg = DeployConfig::default();
        cfg.set("max_fuse", "0").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("max_fuse"));

        // the token budget must cover the widest single chain window
        let mut cfg = DeployConfig::default();
        cfg.set("fuse_tokens", "4").unwrap(); // gamma 8 -> window 9 > 4
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("fuse_tokens"), "{err}");
        // ... unless fusion is off (legacy path never packs)
        cfg.set("fuse", "off").unwrap();
        assert!(cfg.validate().is_ok());
        // ... and tree deployments run solo rounds, so no budget bound
        cfg.set("fuse", "on").unwrap();
        cfg.set("draft_shape", "tree:4x3").unwrap();
        assert!(cfg.validate().is_ok());

        // max_batch = 0 stays a config-time error, not a downstream panic
        let mut cfg = DeployConfig::default();
        cfg.set("max_batch", "0").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("max_batch"));

        // bad switch values surface
        let mut cfg = DeployConfig::default();
        assert!(cfg.set("fuse", "maybe").is_err());
    }

    #[test]
    fn controller_key_parses_kinds() {
        let mut cfg = DeployConfig::default();
        assert_eq!(cfg.decode.controller, ControllerKind::Static);
        cfg.set("controller", "aimd").unwrap();
        assert_eq!(cfg.decode.controller, ControllerKind::Aimd);
        cfg.set("decode.controller", "static").unwrap();
        assert_eq!(cfg.decode.controller, ControllerKind::Static);
        let err = cfg.set("controller", "pid").unwrap_err().to_string();
        assert!(err.contains("accepted forms"), "{err}");
    }

    #[test]
    fn overlap_key_parses_on_off() {
        let mut cfg = DeployConfig::default();
        assert!(cfg.decode.overlap, "overlap defaults on");
        cfg.set("overlap", "off").unwrap();
        assert!(!cfg.decode.overlap);
        cfg.set("decode.overlap", "on").unwrap();
        assert!(cfg.decode.overlap);
        let err = cfg.set("overlap", "maybe").unwrap_err().to_string();
        assert!(err.contains("on|off"), "{err}");
    }

    #[test]
    fn validate_surfaces_clear_errors() {
        let mut cfg = DeployConfig::default();
        assert!(cfg.validate().is_ok());
        // the γ = 0 underflow class is now a config-time error
        cfg.set("gamma", "0").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("gamma") && err.contains("baseline"), "{err}");
        cfg.set("gamma", "8").unwrap();
        cfg.set("max_new_tokens", "0").unwrap();
        assert!(cfg.validate().is_err());
        cfg.set("max_new_tokens", "64").unwrap();
        cfg.set("tau", "1.5").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("tau"));
        cfg.set("tau", "0.2").unwrap();
        cfg.set("nodes", "0").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("n_nodes"));
        cfg.set("nodes", "4").unwrap();
        cfg.set("link_ms", "-3").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("link_ms"));
    }

    #[test]
    fn draft_shape_key() {
        let mut cfg = DeployConfig::default();
        cfg.set("decode.draft_shape", "tree:2x3").unwrap();
        assert!(!cfg.decode.shape.is_chain());
        cfg.set("draft_shape", "chain").unwrap();
        assert!(cfg.decode.shape.is_chain());
        let err = cfg.set("draft_shape", "tree:x3").unwrap_err().to_string();
        assert!(err.contains("accepted forms"), "{err}");
    }

    #[test]
    fn apply_args_surfaces_bad_values_for_known_keys() {
        fn args_with(k: &str, v: &str) -> Args {
            let mut a = Args::default();
            a.options.insert(k.to_string(), v.to_string());
            a
        }
        let mut cfg = DeployConfig::default();
        // foreign keys (other drivers' options, e.g. --out) pass through
        cfg.apply_args(&args_with("out", "deploy.toml")).unwrap();
        // a bad value for a recognized key must error with the accepted
        // forms, not silently fall back to the default
        let err = cfg
            .apply_args(&args_with("draft_shape", "tree:x3"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("accepted forms"), "{err}");
        assert!(cfg.decode.shape.is_chain(), "failed parse must not mutate");
        // bad numeric values surface too; dotted typos still rejected
        assert!(cfg.apply_args(&args_with("nodes", "abc")).is_err());
        assert!(cfg.apply_args(&args_with("decode.bogus", "1")).is_err());
    }

    #[test]
    fn unknown_keys_rejected() {
        let mut cfg = DeployConfig::default();
        assert!(cfg.set("decode.bogus", "1").is_err());
        assert!(cfg.set("policy", "bogus").is_err());
    }

    #[test]
    fn topology_from_config() {
        let mut cfg = DeployConfig::default();
        cfg.set("nodes", "4").unwrap();
        cfg.set("link_ms", "2.5").unwrap();
        let topo = cfg.topology();
        assert_eq!(topo.n_nodes, 4);
        assert_eq!(topo.mean_t1(), 2_500_000);
    }

    #[test]
    fn per_hop_link_ms_parses_validates_and_builds_a_chain() {
        let mut cfg = DeployConfig::default();
        cfg.set("nodes", "4").unwrap();
        cfg.set("link_ms", "5,40,5").unwrap();
        assert_eq!(cfg.link_ms_hops, vec![5.0, 40.0, 5.0]);
        assert!((cfg.link_ms - 50.0 / 3.0).abs() < 1e-9, "scalar tracks the mean");
        assert!(cfg.validate().is_ok());
        let topo = cfg.topology();
        assert_eq!(topo.n_nodes, 4);
        assert_eq!(topo.hop(1).base_ns, 40_000_000);
        // return hop reuses the last forward value
        assert_eq!(topo.hop(3).base_ns, 5_000_000);

        // wrong list length for the node count is a config-time error
        cfg.set("nodes", "3").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("forward hop"), "{err}");
        // negative hop values surface too
        cfg.set("nodes", "4").unwrap();
        cfg.set("link_ms", "5,-1,5").unwrap();
        assert!(cfg.validate().is_err());
        // a scalar resets to uniform links
        cfg.set("link_ms", "15").unwrap();
        assert!(cfg.link_ms_hops.is_empty());
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn calibration_knobs_parse_validate_and_roundtrip() {
        let mut cfg = DeployConfig::default();
        assert!(!cfg.calibrate, "calibration defaults off");
        assert!((cfg.straggler_factor - 3.0).abs() < 1e-9);
        cfg.set("calibrate", "on").unwrap();
        cfg.set("straggler_factor", "2.5").unwrap();
        assert!(cfg.validate().is_ok());
        let text = cfg.to_toml();
        assert!(text.contains("calibrate = \"on\""), "{text}");
        let mut cfg2 = DeployConfig::default();
        for (k, v) in &parse_toml_lite(&text).unwrap() {
            cfg2.set(k, v).unwrap();
        }
        assert!(cfg2.calibrate);
        assert!((cfg2.straggler_factor - 2.5).abs() < 1e-9);
        // a factor <= 1 would flag every link — config-time error
        cfg.set("straggler_factor", "1.0").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("straggler_factor"), "{err}");
        assert!(cfg.set("calibrate", "maybe").is_err());
    }

    #[test]
    fn serving_tier_knobs_parse_validate_and_roundtrip() {
        let cfg = DeployConfig::default();
        assert_eq!(cfg.shards, 1, "single coordinator by default");
        assert_eq!(cfg.placement, Placement::LeastLoaded);
        assert_eq!(cfg.kv_page_tokens, 16);
        assert_eq!(cfg.arrival_rps, 0.0, "closed-loop by default");
        assert!(cfg.validate().is_ok());

        // shards = 0 is a config-time error
        let mut cfg = DeployConfig::default();
        cfg.set("shards", "0").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("shards"));

        // kv_page_tokens bounded by the per-sequence slot capacity
        let mut cfg = DeployConfig::default();
        cfg.set("kv_page_tokens", "0").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("kv_page_tokens"));
        let too_big = cfg.slot_tokens() + 1;
        cfg.set("kv_page_tokens", &too_big.to_string()).unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("slot capacity"), "{err}");
        cfg.set("kv_page_tokens", &cfg.slot_tokens().to_string()).unwrap();
        assert!(cfg.validate().is_ok(), "page = slot capacity is the degenerate 1-page pool");

        // placement parse errors are config errors, not panics
        let mut cfg = DeployConfig::default();
        let err = cfg.set("placement", "round-robin").unwrap_err().to_string();
        assert!(err.contains("least-loaded"), "{err}");
        cfg.set("placement", "hash").unwrap();
        assert_eq!(cfg.placement, Placement::Hash);

        // arrival_rps must be a non-negative rate
        let mut cfg = DeployConfig::default();
        cfg.set("arrival_rps", "-5").unwrap();
        assert!(cfg.validate().unwrap_err().to_string().contains("arrival_rps"));

        // round-trip through the TOML-lite renderer
        let mut cfg = DeployConfig::default();
        cfg.set("shards", "4").unwrap();
        cfg.set("placement", "hash").unwrap();
        cfg.set("kv_page_tokens", "32").unwrap();
        cfg.set("arrival_rps", "250").unwrap();
        let text = cfg.to_toml();
        let mut cfg2 = DeployConfig::default();
        for (k, v) in &parse_toml_lite(&text).unwrap() {
            cfg2.set(k, v).unwrap();
        }
        assert_eq!(cfg2.shards, 4);
        assert_eq!(cfg2.placement, Placement::Hash);
        assert_eq!(cfg2.kv_page_tokens, 32);
        assert!((cfg2.arrival_rps - 250.0).abs() < 1e-9);
    }

    #[test]
    fn per_hop_link_ms_roundtrips_through_toml() {
        let mut cfg = DeployConfig::default();
        cfg.set("link_ms", "5,40,5").unwrap();
        let text = cfg.to_toml();
        assert!(text.contains("link_ms = \"5,40,5\""), "{text}");
        let mut cfg2 = DeployConfig::default();
        for (k, v) in &parse_toml_lite(&text).unwrap() {
            cfg2.set(k, v).unwrap();
        }
        assert_eq!(cfg2.link_ms_hops, vec![5.0, 40.0, 5.0]);
        assert_eq!(cfg2.link_ms, cfg.link_ms);
    }
}
