//! Host-side sampling & distribution utilities.
//!
//! The hot path samples inside the AOT artifacts (draft step fuses its own
//! CDF inversion; the verify kernel resamples residuals), so these
//! routines serve the *baselines*, the accuracy evaluator, and tests.
//! They intentionally mirror the kernel semantics (same CDF convention:
//! token = #{i : cdf_i <= u}) so cross-layer checks are exact.
//!
//! Every routine on a decode-round path has a **buffer-taking** form
//! (`softmax` always had one; [`sample_logits_into`], [`top_k_filter_with`],
//! [`top_p_filter_with`], [`top_k_indices_with`] extend the idiom): the
//! caller owns the scratch (`util::scratch::RoundScratch`), the function
//! only `clear()`s and refills it, so steady-state rounds allocate
//! nothing. The allocating spellings remain as thin wrappers for tests
//! and one-shot callers, and the filter kernels keep their exact legacy
//! semantics (same keep-sets, same float arithmetic) — pinned by the
//! equivalence property tests below.

use crate::util::rng::Rng;

/// Numerically stable in-place softmax; returns the entropy (nats).
pub fn softmax(logits: &[f32], out: &mut Vec<f32>) -> f32 {
    out.clear();
    out.reserve(logits.len());
    let mut max = f32::NEG_INFINITY;
    for &x in logits {
        max = max.max(x);
    }
    let mut sum = 0f32;
    for &x in logits {
        let e = (x - max).exp();
        out.push(e);
        sum += e;
    }
    let inv = 1.0 / sum;
    let mut entropy = 0f32;
    for p in out.iter_mut() {
        *p *= inv;
        if *p > 0.0 {
            entropy -= *p * p.ln();
        }
    }
    entropy
}

/// Softmax with temperature; `temp <= 0` produces a one-hot argmax.
/// Allocation-free: the scaling is fused into the softmax loops (the
/// intermediate values are exactly the old `x / temp` vector, so the
/// output is bit-identical to scaling first and softmaxing after).
pub fn softmax_with_temp(logits: &[f32], temp: f32, out: &mut Vec<f32>) {
    if temp <= 0.0 {
        let am = argmax(logits);
        out.clear();
        out.resize(logits.len(), 0.0);
        out[am] = 1.0;
        return;
    }
    out.clear();
    out.reserve(logits.len());
    let mut max = f32::NEG_INFINITY;
    for &x in logits {
        max = max.max(x / temp);
    }
    let mut sum = 0f32;
    for &x in logits {
        let e = (x / temp - max).exp();
        out.push(e);
        sum += e;
    }
    let inv = 1.0 / sum;
    for p in out.iter_mut() {
        *p *= inv;
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Inverse-CDF categorical sample matching the kernel convention
/// (token = #{i : cdf_i <= u}, clamped to V-1).
pub fn sample_cdf(probs: &[f32], u: f32) -> usize {
    let mut cdf = 0f32;
    let mut idx = 0usize;
    for &p in probs {
        cdf += p;
        if cdf <= u {
            idx += 1;
        } else {
            break;
        }
    }
    idx.min(probs.len() - 1)
}

/// Sample from logits at a temperature (temp <= 0 → greedy argmax).
pub fn sample_logits(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    sample_logits_with(logits, temp, rng.f32())
}

/// [`sample_logits`] with an explicit uniform — the counter-based-RNG
/// form the decode engine uses, whose draws are keyed on position so
/// they are independent of evaluation order (see `util::rng::uniform_at`).
// dsd-lint: allow(hot-path-alloc): allocating wrapper for tests/one-shot callers; rounds use sample_logits_into
pub fn sample_logits_with(logits: &[f32], temp: f32, u: f32) -> usize {
    let mut probs = Vec::new();
    sample_logits_into(logits, temp, u, &mut probs)
}

/// [`sample_logits_with`] over a caller-owned probability buffer — the
/// zero-allocation hot-path form (the decode round loops thread their
/// `RoundScratch::probs` through here).
pub fn sample_logits_into(logits: &[f32], temp: f32, u: f32, probs: &mut Vec<f32>) -> usize {
    if temp <= 0.0 {
        return argmax(logits);
    }
    softmax_with_temp(logits, temp, probs);
    sample_cdf(probs, u)
}

/// Top-k filtering: keep the k largest logits, set the rest to -inf.
pub fn top_k_filter(logits: &mut [f32], k: usize) {
    let mut scratch = Vec::new();
    top_k_filter_with(logits, k, &mut scratch);
}

/// [`top_k_filter`] over a caller-owned value buffer, with the
/// clone-and-full-sort replaced by `select_nth_unstable_by` partial
/// selection (O(V) expected instead of O(V log V)). The threshold is the
/// k-th largest value — exactly what the full sort produced — and the
/// keep-exactly-k-under-ties scan is unchanged, so the output is
/// identical to the legacy kernel (property-tested below).
pub fn top_k_filter_with(logits: &mut [f32], k: usize, scratch: &mut Vec<f32>) {
    if k == 0 || k >= logits.len() {
        return;
    }
    scratch.clear();
    scratch.extend_from_slice(logits);
    scratch.select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
    let threshold = scratch[k - 1];
    let mut kept = 0;
    for x in logits.iter_mut() {
        // Keep exactly k entries even under ties.
        if *x >= threshold && kept < k {
            kept += 1;
        } else {
            *x = f32::NEG_INFINITY;
        }
    }
}

/// Nucleus (top-p) filtering on a probability vector (renormalized).
pub fn top_p_filter(probs: &mut [f32], p: f32) {
    let mut idx = Vec::new();
    top_p_filter_with(probs, p, &mut idx);
}

/// [`top_p_filter`] over a caller-owned index buffer. The legacy kernel
/// built a `HashSet<usize>` of kept indices and probed it once per vocab
/// entry (O(V) hashing per sampled token); the sorted prefix already IS
/// the keep set, so the non-kept suffix is zeroed directly and the
/// renormalizer sums in index order — the identical keep set and float
/// totals (adding the zeroed entries contributes exact 0.0 terms), with
/// no hashing and no allocation. The tie order matches the legacy stable
/// sort because the comparator breaks prob-ties by ascending index.
pub fn top_p_filter_with(probs: &mut [f32], p: f32, idx: &mut Vec<usize>) {
    if p >= 1.0 {
        return;
    }
    idx.clear();
    idx.extend(0..probs.len());
    idx.sort_unstable_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap().then(a.cmp(&b)));
    let mut cum = 0f32;
    let mut cut = probs.len();
    for (rank, &i) in idx.iter().enumerate() {
        cum += probs[i];
        if cum >= p {
            cut = rank + 1;
            break;
        }
    }
    for &i in &idx[cut..] {
        probs[i] = 0.0;
    }
    let mut total = 0f32;
    for &q in probs.iter() {
        total += q;
    }
    if total > 0.0 {
        for q in probs.iter_mut() {
            *q /= total;
        }
    }
}

/// Indices of the top-`k` values, descending (ties: lower index first),
/// written into `idx` — the tree-expansion picker. Partial selection +
/// a k-prefix sort instead of a full index sort; the comparator is a
/// total order (index tie-break), so the result equals the first k
/// entries of the legacy full stable sort.
pub fn top_k_indices_with(values: &[f32], k: usize, idx: &mut Vec<usize>) {
    idx.clear();
    if k == 0 {
        return;
    }
    idx.extend(0..values.len());
    let cmp = |a: &usize, b: &usize| {
        values[*b]
            .partial_cmp(&values[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
}

/// Total-variation overlap `Σ min(p, q)` — the quantity the verify kernel
/// calls NormMatch, and the expected single-token acceptance probability
/// of lossless speculative decoding.
pub fn overlap(p: &[f32], q: &[f32]) -> f32 {
    p.iter().zip(q).map(|(&a, &b)| a.min(b)).sum()
}

/// KL(p || q) in nats, with epsilon smoothing on q.
pub fn kl_divergence(p: &[f32], q: &[f32]) -> f32 {
    let eps = 1e-9f32;
    p.iter()
        .zip(q)
        .filter(|(&a, _)| a > 0.0)
        .map(|(&a, &b)| a * (a / (b + eps)).ln())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut out = Vec::new();
        let h = softmax(&[1.0, 2.0, 3.0], &mut out);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(out[2] > out[1] && out[1] > out[0]);
        assert!(h > 0.0 && h < (3f32).ln() + 1e-6);
    }

    #[test]
    fn greedy_temp_is_one_hot() {
        let mut out = Vec::new();
        softmax_with_temp(&[0.1, 5.0, 0.2], 0.0, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn cdf_sampling_matches_kernel_convention() {
        let probs = [0.25f32, 0.25, 0.5];
        assert_eq!(sample_cdf(&probs, 0.0), 0);
        assert_eq!(sample_cdf(&probs, 0.24), 0);
        assert_eq!(sample_cdf(&probs, 0.25), 1);
        assert_eq!(sample_cdf(&probs, 0.49), 1);
        assert_eq!(sample_cdf(&probs, 0.99), 2);
    }

    #[test]
    fn sampling_distribution_is_right() {
        let mut rng = Rng::new(11);
        let logits = [0.0f32, (3.0f32).ln()]; // p = [0.25, 0.75]
        let n = 40_000;
        let ones = (0..n)
            .filter(|_| sample_logits(&logits, 1.0, &mut rng) == 1)
            .count();
        let p = ones as f64 / n as f64;
        assert!((p - 0.75).abs() < 0.01, "{p}");
    }

    #[test]
    fn top_k_keeps_k() {
        let mut l = vec![1.0, 5.0, 3.0, 2.0];
        top_k_filter(&mut l, 2);
        let kept = l.iter().filter(|x| x.is_finite()).count();
        assert_eq!(kept, 2);
        assert!(l[1].is_finite() && l[2].is_finite());
    }

    #[test]
    fn top_p_renormalizes() {
        let mut p = vec![0.5f32, 0.3, 0.15, 0.05];
        top_p_filter(&mut p, 0.8);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert_eq!(p[3], 0.0);
        assert_eq!(p[2], 0.0);
    }

    #[test]
    fn overlap_bounds() {
        let p = [0.5f32, 0.5];
        let q = [0.5f32, 0.5];
        assert!((overlap(&p, &q) - 1.0).abs() < 1e-6);
        let r = [1.0f32, 0.0];
        let s = [0.0f32, 1.0];
        assert_eq!(overlap(&r, &s), 0.0);
    }

    #[test]
    fn kl_zero_iff_equal() {
        let p = [0.25f32, 0.75];
        assert!(kl_divergence(&p, &p).abs() < 1e-5);
        let q = [0.75f32, 0.25];
        assert!(kl_divergence(&p, &q) > 0.1);
    }

    // ----- equivalence pins: buffer-taking kernels == legacy kernels -----

    /// The pre-scratch top-k (clone + full sort): the reference the
    /// select_nth_unstable version must reproduce exactly.
    fn legacy_top_k_filter(logits: &mut [f32], k: usize) {
        if k == 0 || k >= logits.len() {
            return;
        }
        let mut sorted: Vec<f32> = logits.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let threshold = sorted[k - 1];
        let mut kept = 0;
        for x in logits.iter_mut() {
            if *x >= threshold && kept < k {
                kept += 1;
            } else {
                *x = f32::NEG_INFINITY;
            }
        }
    }

    /// The pre-scratch top-p (stable index sort + HashSet membership).
    fn legacy_top_p_filter(probs: &mut [f32], p: f32) {
        if p >= 1.0 {
            return;
        }
        let mut idx: Vec<usize> = (0..probs.len()).collect();
        idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut cum = 0f32;
        let mut cut = probs.len();
        for (rank, &i) in idx.iter().enumerate() {
            cum += probs[i];
            if cum >= p {
                cut = rank + 1;
                break;
            }
        }
        let keep: std::collections::HashSet<usize> = idx[..cut].iter().copied().collect();
        let mut total = 0f32;
        for (i, q) in probs.iter_mut().enumerate() {
            if keep.contains(&i) {
                total += *q;
            } else {
                *q = 0.0;
            }
        }
        if total > 0.0 {
            for q in probs.iter_mut() {
                *q /= total;
            }
        }
    }

    #[test]
    fn top_k_select_matches_legacy_sort_exactly() {
        let mut rng = Rng::new(71);
        let mut scratch = Vec::new();
        for trial in 0..300 {
            let n = 1 + (trial % 97);
            let mut a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            // force ties on a fraction of trials
            if trial % 3 == 0 && n > 4 {
                let v = a[0];
                a[1] = v;
                a[n / 2] = v;
            }
            let mut b = a.clone();
            let k = (trial * 7) % (n + 2); // includes 0 and >= n edges
            legacy_top_k_filter(&mut a, k);
            top_k_filter_with(&mut b, k, &mut scratch);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "trial {trial} k={k}"
            );
        }
    }

    #[test]
    fn top_p_mask_matches_legacy_hashset_exactly() {
        let mut rng = Rng::new(72);
        let mut idx = Vec::new();
        let mut probs_buf = Vec::new();
        for trial in 0..300 {
            let n = 2 + (trial % 63);
            let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 2.0).collect();
            softmax(&logits, &mut probs_buf);
            let mut a = probs_buf.clone();
            let mut b = probs_buf.clone();
            let p = [0.05f32, 0.3, 0.5, 0.8, 0.95, 0.999, 1.0][trial % 7];
            legacy_top_p_filter(&mut a, p);
            top_p_filter_with(&mut b, p, &mut idx);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "trial {trial} p={p}"
            );
        }
    }

    #[test]
    fn softmax_with_temp_matches_scale_then_softmax_exactly() {
        let mut rng = Rng::new(73);
        for trial in 0..100 {
            let n = 1 + (trial % 40);
            let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 3.0).collect();
            let temp = [0.25f32, 0.7, 1.0, 1.9][trial % 4];
            // reference: materialize the scaled vector, then plain softmax
            let scaled: Vec<f32> = logits.iter().map(|&x| x / temp).collect();
            let mut want = Vec::new();
            softmax(&scaled, &mut want);
            let mut got = Vec::new();
            softmax_with_temp(&logits, temp, &mut got);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "trial {trial} temp={temp}"
            );
        }
    }

    #[test]
    fn sample_logits_into_matches_allocating_form() {
        let mut rng = Rng::new(74);
        let mut buf = Vec::new();
        for _ in 0..200 {
            let logits: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
            let u = rng.f32();
            for temp in [0.0f32, 0.5, 1.0] {
                assert_eq!(
                    sample_logits_with(&logits, temp, u),
                    sample_logits_into(&logits, temp, u, &mut buf)
                );
            }
        }
    }

    #[test]
    fn top_k_indices_match_full_sort_reference() {
        let mut rng = Rng::new(75);
        let mut idx = Vec::new();
        for trial in 0..200 {
            let n = 1 + (trial % 70);
            let mut vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            if trial % 4 == 0 && n > 3 {
                vals[n - 1] = vals[0]; // tie across distant indices
            }
            let k = (trial * 3) % (n + 2);
            // reference: full stable sort, then truncate — the legacy
            // spec::tree::top_k
            let mut want: Vec<usize> = (0..n).collect();
            want.sort_by(|&a, &b| {
                vals[b]
                    .partial_cmp(&vals[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            want.truncate(k);
            top_k_indices_with(&vals, k, &mut idx);
            assert_eq!(want, idx, "trial {trial} k={k}");
        }
    }
}
