//! Host-side sampling & distribution utilities.
//!
//! The hot path samples inside the AOT artifacts (draft step fuses its own
//! CDF inversion; the verify kernel resamples residuals), so these
//! routines serve the *baselines*, the accuracy evaluator, and tests.
//! They intentionally mirror the kernel semantics (same CDF convention:
//! token = #{i : cdf_i <= u}) so cross-layer checks are exact.

use crate::util::rng::Rng;

/// Numerically stable in-place softmax; returns the entropy (nats).
pub fn softmax(logits: &[f32], out: &mut Vec<f32>) -> f32 {
    out.clear();
    out.reserve(logits.len());
    let mut max = f32::NEG_INFINITY;
    for &x in logits {
        max = max.max(x);
    }
    let mut sum = 0f32;
    for &x in logits {
        let e = (x - max).exp();
        out.push(e);
        sum += e;
    }
    let inv = 1.0 / sum;
    let mut entropy = 0f32;
    for p in out.iter_mut() {
        *p *= inv;
        if *p > 0.0 {
            entropy -= *p * p.ln();
        }
    }
    entropy
}

/// Softmax with temperature; `temp <= 0` produces a one-hot argmax.
pub fn softmax_with_temp(logits: &[f32], temp: f32, out: &mut Vec<f32>) {
    if temp <= 0.0 {
        let am = argmax(logits);
        out.clear();
        out.resize(logits.len(), 0.0);
        out[am] = 1.0;
        return;
    }
    let scaled: Vec<f32> = logits.iter().map(|&x| x / temp).collect();
    softmax(&scaled, out);
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Inverse-CDF categorical sample matching the kernel convention
/// (token = #{i : cdf_i <= u}, clamped to V-1).
pub fn sample_cdf(probs: &[f32], u: f32) -> usize {
    let mut cdf = 0f32;
    let mut idx = 0usize;
    for &p in probs {
        cdf += p;
        if cdf <= u {
            idx += 1;
        } else {
            break;
        }
    }
    idx.min(probs.len() - 1)
}

/// Sample from logits at a temperature (temp <= 0 → greedy argmax).
pub fn sample_logits(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    sample_logits_with(logits, temp, rng.f32())
}

/// [`sample_logits`] with an explicit uniform — the counter-based-RNG
/// form the decode engine uses, whose draws are keyed on position so
/// they are independent of evaluation order (see `util::rng::uniform_at`).
pub fn sample_logits_with(logits: &[f32], temp: f32, u: f32) -> usize {
    if temp <= 0.0 {
        return argmax(logits);
    }
    let mut probs = Vec::new();
    softmax_with_temp(logits, temp, &mut probs);
    sample_cdf(&probs, u)
}

/// Top-k filtering: keep the k largest logits, set the rest to -inf.
pub fn top_k_filter(logits: &mut [f32], k: usize) {
    if k == 0 || k >= logits.len() {
        return;
    }
    let mut sorted: Vec<f32> = logits.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let threshold = sorted[k - 1];
    let mut kept = 0;
    for x in logits.iter_mut() {
        // Keep exactly k entries even under ties.
        if *x >= threshold && kept < k {
            kept += 1;
        } else {
            *x = f32::NEG_INFINITY;
        }
    }
}

/// Nucleus (top-p) filtering on a probability vector (renormalized).
pub fn top_p_filter(probs: &mut [f32], p: f32) {
    if p >= 1.0 {
        return;
    }
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let mut cum = 0f32;
    let mut cut = probs.len();
    for (rank, &i) in idx.iter().enumerate() {
        cum += probs[i];
        if cum >= p {
            cut = rank + 1;
            break;
        }
    }
    let keep: std::collections::HashSet<usize> = idx[..cut].iter().copied().collect();
    let mut total = 0f32;
    for (i, q) in probs.iter_mut().enumerate() {
        if keep.contains(&i) {
            total += *q;
        } else {
            *q = 0.0;
        }
    }
    if total > 0.0 {
        for q in probs.iter_mut() {
            *q /= total;
        }
    }
}

/// Total-variation overlap `Σ min(p, q)` — the quantity the verify kernel
/// calls NormMatch, and the expected single-token acceptance probability
/// of lossless speculative decoding.
pub fn overlap(p: &[f32], q: &[f32]) -> f32 {
    p.iter().zip(q).map(|(&a, &b)| a.min(b)).sum()
}

/// KL(p || q) in nats, with epsilon smoothing on q.
pub fn kl_divergence(p: &[f32], q: &[f32]) -> f32 {
    let eps = 1e-9f32;
    p.iter()
        .zip(q)
        .filter(|(&a, _)| a > 0.0)
        .map(|(&a, &b)| a * (a / (b + eps)).ln())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut out = Vec::new();
        let h = softmax(&[1.0, 2.0, 3.0], &mut out);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(out[2] > out[1] && out[1] > out[0]);
        assert!(h > 0.0 && h < (3f32).ln() + 1e-6);
    }

    #[test]
    fn greedy_temp_is_one_hot() {
        let mut out = Vec::new();
        softmax_with_temp(&[0.1, 5.0, 0.2], 0.0, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn cdf_sampling_matches_kernel_convention() {
        let probs = [0.25f32, 0.25, 0.5];
        assert_eq!(sample_cdf(&probs, 0.0), 0);
        assert_eq!(sample_cdf(&probs, 0.24), 0);
        assert_eq!(sample_cdf(&probs, 0.25), 1);
        assert_eq!(sample_cdf(&probs, 0.49), 1);
        assert_eq!(sample_cdf(&probs, 0.99), 2);
    }

    #[test]
    fn sampling_distribution_is_right() {
        let mut rng = Rng::new(11);
        let logits = [0.0f32, (3.0f32).ln()]; // p = [0.25, 0.75]
        let n = 40_000;
        let ones = (0..n)
            .filter(|_| sample_logits(&logits, 1.0, &mut rng) == 1)
            .count();
        let p = ones as f64 / n as f64;
        assert!((p - 0.75).abs() < 0.01, "{p}");
    }

    #[test]
    fn top_k_keeps_k() {
        let mut l = vec![1.0, 5.0, 3.0, 2.0];
        top_k_filter(&mut l, 2);
        let kept = l.iter().filter(|x| x.is_finite()).count();
        assert_eq!(kept, 2);
        assert!(l[1].is_finite() && l[2].is_finite());
    }

    #[test]
    fn top_p_renormalizes() {
        let mut p = vec![0.5f32, 0.3, 0.15, 0.05];
        top_p_filter(&mut p, 0.8);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert_eq!(p[3], 0.0);
        assert_eq!(p[2], 0.0);
    }

    #[test]
    fn overlap_bounds() {
        let p = [0.5f32, 0.5];
        let q = [0.5f32, 0.5];
        assert!((overlap(&p, &q) - 1.0).abs() < 1e-6);
        let r = [1.0f32, 0.0];
        let s = [0.0f32, 1.0];
        assert_eq!(overlap(&r, &s), 0.0);
    }

    #[test]
    fn kl_zero_iff_equal() {
        let p = [0.25f32, 0.75];
        assert!(kl_divergence(&p, &p).abs() < 1e-5);
        let q = [0.75f32, 0.25];
        assert!(kl_divergence(&p, &q) > 0.1);
    }
}
