//! Host-side sampling & distribution utilities.
//!
//! The hot path samples inside the AOT artifacts (draft step fuses its own
//! CDF inversion; the verify kernel resamples residuals), so these
//! routines serve the *baselines*, the accuracy evaluator, and tests.
//! They intentionally mirror the kernel semantics (same CDF convention:
//! token = #{i : cdf_i <= u}) so cross-layer checks are exact.
//!
//! Every routine on a decode-round path has a **buffer-taking** form
//! (`softmax` always had one; [`sample_logits_into`], [`top_k_filter_with`],
//! [`top_p_filter_with`], [`top_k_indices_with`] extend the idiom): the
//! caller owns the scratch (`util::scratch::RoundScratch`), the function
//! only `clear()`s and refills it, so steady-state rounds allocate
//! nothing. The allocating spellings remain as thin wrappers for tests
//! and one-shot callers, and the filter kernels keep their exact legacy
//! semantics (same keep-sets, same float arithmetic) — pinned by the
//! equivalence property tests below.

use crate::kernels;
use crate::util::rng::Rng;

/// Numerically stable softmax into `out`; returns the entropy (nats).
/// Delegates to the canonical lane-chunked kernel
/// ([`kernels::softmax_entropy_into`]) — the max is bit-identical to the
/// scalar scan, the exp sum is lane-treed (tight-ulp).
pub fn softmax(logits: &[f32], out: &mut Vec<f32>) -> f32 {
    kernels::softmax_entropy_into(logits, 1.0, out)
}

/// Softmax with temperature; `temp <= 0` produces a one-hot argmax.
/// The scaling is fused into the kernel passes as `x · (1/temp)` — the
/// output is bit-identical to materializing the scaled vector first and
/// softmaxing after (pinned below), and the entropy `ln` pass of
/// [`softmax`] is skipped entirely.
pub fn softmax_with_temp(logits: &[f32], temp: f32, out: &mut Vec<f32>) {
    if temp <= 0.0 {
        let am = argmax(logits);
        out.clear();
        out.resize(logits.len(), 0.0);
        out[am] = 1.0;
        return;
    }
    kernels::softmax_into(logits, 1.0 / temp, out);
}

/// First-index argmax ([`kernels::argmax`]: lane-chunked, exactly the
/// scalar first-wins strict-`>` scan for non-NaN rows).
pub fn argmax(xs: &[f32]) -> usize {
    kernels::argmax(xs)
}

/// Inverse-CDF categorical sample matching the kernel convention
/// (token = #{i : cdf_i <= u}, clamped to V-1).
pub fn sample_cdf(probs: &[f32], u: f32) -> usize {
    kernels::cdf_walk(probs, u)
}

/// Sample from logits at a temperature (temp <= 0 → greedy argmax).
pub fn sample_logits(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    sample_logits_with(logits, temp, rng.f32())
}

/// [`sample_logits`] with an explicit uniform — the counter-based-RNG
/// form the decode engine uses, whose draws are keyed on position so
/// they are independent of evaluation order (see `util::rng::uniform_at`).
// dsd-lint: allow(hot-path-alloc): allocating wrapper for tests/one-shot callers; rounds use sample_logits_into
pub fn sample_logits_with(logits: &[f32], temp: f32, u: f32) -> usize {
    let mut probs = Vec::new();
    sample_logits_into(logits, temp, u, &mut probs)
}

/// [`sample_logits_with`] over a caller-owned probability buffer — the
/// zero-allocation hot-path form (the decode round loops thread their
/// `RoundScratch::probs` through here).
pub fn sample_logits_into(logits: &[f32], temp: f32, u: f32, probs: &mut Vec<f32>) -> usize {
    if temp <= 0.0 {
        return argmax(logits);
    }
    softmax_with_temp(logits, temp, probs);
    sample_cdf(probs, u)
}

/// Top-k filtering: keep the k largest logits, set the rest to -inf.
pub fn top_k_filter(logits: &mut [f32], k: usize) {
    let mut scratch = Vec::new();
    top_k_filter_with(logits, k, &mut scratch);
}

/// [`top_k_filter`] over a caller-owned value buffer, with the
/// clone-and-full-sort replaced by `select_nth_unstable_by` partial
/// selection (O(V) expected instead of O(V log V)). The comparator is
/// `f32::total_cmp` — a total order, so NaN inputs select a threshold
/// deterministically instead of panicking (a NaN threshold keeps
/// nothing: `x >= NaN` is always false) — and it picks the identical
/// threshold on non-NaN rows (`-0.0 < +0.0` under total order, but both
/// compare equal under `>=`, so the keep-set cannot differ). The masking
/// scan is the chunked [`kernels::top_k_mask`], pinned bit-identical to
/// the historical sequential keep-exactly-k scan.
pub fn top_k_filter_with(logits: &mut [f32], k: usize, scratch: &mut Vec<f32>) {
    if k == 0 || k >= logits.len() {
        return;
    }
    scratch.clear();
    scratch.extend_from_slice(logits);
    scratch.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
    let threshold = scratch[k - 1];
    kernels::top_k_mask(logits, threshold, k);
}

/// Nucleus (top-p) filtering on a probability vector (renormalized).
pub fn top_p_filter(probs: &mut [f32], p: f32) {
    let mut idx = Vec::new();
    top_p_filter_with(probs, p, &mut idx);
}

/// [`top_p_filter`] over a caller-owned index buffer. The legacy kernel
/// built a `HashSet<usize>` of kept indices and probed it once per vocab
/// entry (O(V) hashing per sampled token); the sorted prefix already IS
/// the keep set, so the non-kept suffix is zeroed directly and the
/// renormalizer sums in index order — the identical keep set and float
/// totals (adding the zeroed entries contributes exact 0.0 terms), with
/// no hashing and no allocation. The tie order matches the legacy stable
/// sort because the comparator breaks prob-ties by ascending index; it
/// uses `f32::total_cmp`, so a NaN probability yields a deterministic
/// order instead of a comparator panic, and on NaN-free rows (softmax
/// output, the only caller) the order is the one `partial_cmp` produced.
pub fn top_p_filter_with(probs: &mut [f32], p: f32, idx: &mut Vec<usize>) {
    if p >= 1.0 {
        return;
    }
    idx.clear();
    idx.extend(0..probs.len());
    idx.sort_unstable_by(|&a, &b| probs[b].total_cmp(&probs[a]).then(a.cmp(&b)));
    let mut cum = 0f32;
    let mut cut = probs.len();
    for (rank, &i) in idx.iter().enumerate() {
        cum += probs[i];
        if cum >= p {
            cut = rank + 1;
            break;
        }
    }
    for &i in &idx[cut..] {
        probs[i] = 0.0;
    }
    let mut total = 0f32;
    for &q in probs.iter() {
        total += q;
    }
    if total > 0.0 {
        for q in probs.iter_mut() {
            *q /= total;
        }
    }
}

/// Indices of the top-`k` values, descending (ties: lower index first),
/// written into `idx` — the tree-expansion picker. Partial selection +
/// a k-prefix sort instead of a full index sort; the comparator is a
/// total order (index tie-break), so the result equals the first k
/// entries of the legacy full stable sort.
pub fn top_k_indices_with(values: &[f32], k: usize, idx: &mut Vec<usize>) {
    idx.clear();
    if k == 0 {
        return;
    }
    idx.extend(0..values.len());
    let cmp = |a: &usize, b: &usize| {
        values[*b]
            .partial_cmp(&values[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
}

/// Total-variation overlap `Σ min(p, q)` — the quantity the verify kernel
/// calls NormMatch, and the expected single-token acceptance probability
/// of lossless speculative decoding ([`kernels::min_overlap`], lane-treed
/// sum).
pub fn overlap(p: &[f32], q: &[f32]) -> f32 {
    kernels::min_overlap(p, q)
}

/// KL(p || q) in nats, with epsilon smoothing on q.
pub fn kl_divergence(p: &[f32], q: &[f32]) -> f32 {
    let eps = 1e-9f32;
    p.iter()
        .zip(q)
        .filter(|(&a, _)| a > 0.0)
        .map(|(&a, &b)| a * (a / (b + eps)).ln())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut out = Vec::new();
        let h = softmax(&[1.0, 2.0, 3.0], &mut out);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(out[2] > out[1] && out[1] > out[0]);
        assert!(h > 0.0 && h < (3f32).ln() + 1e-6);
    }

    #[test]
    fn greedy_temp_is_one_hot() {
        let mut out = Vec::new();
        softmax_with_temp(&[0.1, 5.0, 0.2], 0.0, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn cdf_sampling_matches_kernel_convention() {
        let probs = [0.25f32, 0.25, 0.5];
        assert_eq!(sample_cdf(&probs, 0.0), 0);
        assert_eq!(sample_cdf(&probs, 0.24), 0);
        assert_eq!(sample_cdf(&probs, 0.25), 1);
        assert_eq!(sample_cdf(&probs, 0.49), 1);
        assert_eq!(sample_cdf(&probs, 0.99), 2);
    }

    #[test]
    fn sampling_distribution_is_right() {
        let mut rng = Rng::new(11);
        let logits = [0.0f32, (3.0f32).ln()]; // p = [0.25, 0.75]
        let n = 40_000;
        let ones = (0..n)
            .filter(|_| sample_logits(&logits, 1.0, &mut rng) == 1)
            .count();
        let p = ones as f64 / n as f64;
        assert!((p - 0.75).abs() < 0.01, "{p}");
    }

    #[test]
    fn top_k_keeps_k() {
        let mut l = vec![1.0, 5.0, 3.0, 2.0];
        top_k_filter(&mut l, 2);
        let kept = l.iter().filter(|x| x.is_finite()).count();
        assert_eq!(kept, 2);
        assert!(l[1].is_finite() && l[2].is_finite());
    }

    #[test]
    fn top_p_renormalizes() {
        let mut p = vec![0.5f32, 0.3, 0.15, 0.05];
        top_p_filter(&mut p, 0.8);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert_eq!(p[3], 0.0);
        assert_eq!(p[2], 0.0);
    }

    #[test]
    fn overlap_bounds() {
        let p = [0.5f32, 0.5];
        let q = [0.5f32, 0.5];
        assert!((overlap(&p, &q) - 1.0).abs() < 1e-6);
        let r = [1.0f32, 0.0];
        let s = [0.0f32, 1.0];
        assert_eq!(overlap(&r, &s), 0.0);
    }

    #[test]
    fn kl_zero_iff_equal() {
        let p = [0.25f32, 0.75];
        assert!(kl_divergence(&p, &p).abs() < 1e-5);
        let q = [0.75f32, 0.25];
        assert!(kl_divergence(&p, &q) > 0.1);
    }

    // ----- equivalence pins: buffer-taking kernels == legacy kernels -----

    /// The pre-scratch top-k (clone + full sort): the reference the
    /// select_nth_unstable version must reproduce exactly.
    fn legacy_top_k_filter(logits: &mut [f32], k: usize) {
        if k == 0 || k >= logits.len() {
            return;
        }
        let mut sorted: Vec<f32> = logits.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let threshold = sorted[k - 1];
        let mut kept = 0;
        for x in logits.iter_mut() {
            if *x >= threshold && kept < k {
                kept += 1;
            } else {
                *x = f32::NEG_INFINITY;
            }
        }
    }

    /// The pre-scratch top-p (stable index sort + HashSet membership).
    fn legacy_top_p_filter(probs: &mut [f32], p: f32) {
        if p >= 1.0 {
            return;
        }
        let mut idx: Vec<usize> = (0..probs.len()).collect();
        idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut cum = 0f32;
        let mut cut = probs.len();
        for (rank, &i) in idx.iter().enumerate() {
            cum += probs[i];
            if cum >= p {
                cut = rank + 1;
                break;
            }
        }
        let keep: std::collections::HashSet<usize> = idx[..cut].iter().copied().collect();
        let mut total = 0f32;
        for (i, q) in probs.iter_mut().enumerate() {
            if keep.contains(&i) {
                total += *q;
            } else {
                *q = 0.0;
            }
        }
        if total > 0.0 {
            for q in probs.iter_mut() {
                *q /= total;
            }
        }
    }

    #[test]
    fn top_k_select_matches_legacy_sort_exactly() {
        let mut rng = Rng::new(71);
        let mut scratch = Vec::new();
        for trial in 0..300 {
            let n = 1 + (trial % 97);
            let mut a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            // force ties on a fraction of trials
            if trial % 3 == 0 && n > 4 {
                let v = a[0];
                a[1] = v;
                a[n / 2] = v;
            }
            let mut b = a.clone();
            let k = (trial * 7) % (n + 2); // includes 0 and >= n edges
            legacy_top_k_filter(&mut a, k);
            top_k_filter_with(&mut b, k, &mut scratch);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "trial {trial} k={k}"
            );
        }
    }

    #[test]
    fn top_p_mask_matches_legacy_hashset_exactly() {
        let mut rng = Rng::new(72);
        let mut idx = Vec::new();
        let mut probs_buf = Vec::new();
        for trial in 0..300 {
            let n = 2 + (trial % 63);
            let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 2.0).collect();
            softmax(&logits, &mut probs_buf);
            let mut a = probs_buf.clone();
            let mut b = probs_buf.clone();
            let p = [0.05f32, 0.3, 0.5, 0.8, 0.95, 0.999, 1.0][trial % 7];
            legacy_top_p_filter(&mut a, p);
            top_p_filter_with(&mut b, p, &mut idx);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "trial {trial} p={p}"
            );
        }
    }

    #[test]
    fn softmax_with_temp_matches_scale_then_softmax_exactly() {
        let mut rng = Rng::new(73);
        for trial in 0..100 {
            let n = 1 + (trial % 40);
            let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 3.0).collect();
            let temp = [0.25f32, 0.7, 1.0, 1.9][trial % 4];
            // reference: materialize the scaled vector, then plain
            // softmax (the kernel fuses `x * (1/temp)` into its passes)
            let scaled: Vec<f32> = logits.iter().map(|&x| x * (1.0 / temp)).collect();
            let mut want = Vec::new();
            softmax(&scaled, &mut want);
            let mut got = Vec::new();
            softmax_with_temp(&logits, temp, &mut got);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "trial {trial} temp={temp}"
            );
        }
    }

    #[test]
    fn sample_logits_into_matches_allocating_form() {
        let mut rng = Rng::new(74);
        let mut buf = Vec::new();
        for _ in 0..200 {
            let logits: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
            let u = rng.f32();
            for temp in [0.0f32, 0.5, 1.0] {
                assert_eq!(
                    sample_logits_with(&logits, temp, u),
                    sample_logits_into(&logits, temp, u, &mut buf)
                );
            }
        }
    }

    #[test]
    fn top_k_indices_match_full_sort_reference() {
        let mut rng = Rng::new(75);
        let mut idx = Vec::new();
        for trial in 0..200 {
            let n = 1 + (trial % 70);
            let mut vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            if trial % 4 == 0 && n > 3 {
                vals[n - 1] = vals[0]; // tie across distant indices
            }
            let k = (trial * 3) % (n + 2);
            // reference: full stable sort, then truncate — the legacy
            // spec::tree::top_k
            let mut want: Vec<usize> = (0..n).collect();
            want.sort_by(|&a, &b| {
                vals[b]
                    .partial_cmp(&vals[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            want.truncate(k);
            top_k_indices_with(&vals, k, &mut idx);
            assert_eq!(want, idx, "trial {trial} k={k}");
        }
    }

    #[test]
    fn filters_tolerate_nan_without_panicking() {
        // The historical comparators were `partial_cmp().unwrap()` — a
        // single NaN logit panicked the sampler. `total_cmp` orders NaN
        // deterministically instead: positive NaN sorts largest, so
        // top-k either never keeps one (NaN-free threshold; `NaN >= t`
        // is false) or keeps nothing at all (NaN threshold), and top-p
        // completes without touching the comparator's unwrap.
        let mut rng = Rng::new(76);
        let mut scratch = Vec::new();
        let mut idx = Vec::new();
        for trial in 0..100 {
            let n = 4 + (trial % 60);
            let mut logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            logits[trial % n] = f32::NAN;
            if trial % 2 == 0 {
                logits[(trial / 2) % n] = f32::NAN;
            }
            let k = 1 + (trial % (n - 1));
            let mut l = logits.clone();
            top_k_filter_with(&mut l, k, &mut scratch);
            assert!(
                l.iter().all(|x| !x.is_nan()),
                "trial {trial}: NaN survived top-k"
            );
            assert!(l.iter().filter(|x| x.is_finite()).count() <= k);

            // top-p on a NaN-poisoned row: must not panic; the row is
            // left deterministic (NaN propagates through the cum/renorm
            // arithmetic, exactly as it would have before the sort).
            let mut probs = logits;
            top_p_filter_with(&mut probs, 0.6, &mut idx);
            assert_eq!(probs.len(), n, "trial {trial}");
        }
    }

    #[test]
    fn total_cmp_keeps_identical_sets_on_non_nan_inputs() {
        // On NaN-free rows the total_cmp comparators must reproduce the
        // partial_cmp behavior exactly — including ±0.0 rows, where the
        // orders differ but the masks cannot (0.0 >= -0.0 both ways).
        let mut rng = Rng::new(77);
        let mut scratch = Vec::new();
        for trial in 0..200 {
            let n = 2 + (trial % 50);
            let mut vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            if trial % 3 == 0 {
                vals[0] = 0.0;
                vals[n / 2] = -0.0;
            }
            let k = 1 + (trial % n);
            let mut want = vals.clone();
            legacy_top_k_filter(&mut want, k);
            let mut got = vals;
            top_k_filter_with(&mut got, k, &mut scratch);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "trial {trial} k={k}"
            );
        }
    }
}
