//! Experiment harness: the shared machinery behind every paper-table
//! bench and example — run a workload under several policies on identical
//! requests, compute the accuracy proxy against the target-greedy
//! reference, and emit table rows.
//!
//! Accuracy protocol (DESIGN.md §5): **teacher-forced greedy agreement**
//! (GTA). After a system produces its output, we run the target model
//! once over `prompt ⊕ output` (teacher-forced) and measure the fraction
//! of generated positions whose token equals the target's argmax *in that
//! context*. Properties that make this the right proxy:
//!   * greedy decoding scores exactly 1.0 (it IS the argmax path);
//!   * a pure target sample at temperature T scores E[P(argmax)] — the
//!     task's intrinsic "Base Acc" at that temperature;
//!   * strict speculative decoding is distribution-lossless, so it scores
//!     Base Acc up to noise;
//!   * τ-relaxation admits tokens from the draft-blended distribution and
//!     shows up as a drift below Base Acc — the effect Table 1 tracks.
//! (Naive rollout-vs-rollout agreement collapses to chance after the
//! first divergent sample and cannot distinguish systems.)

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::Result;

use crate::config::DeployConfig;
use crate::coordinator::Coordinator;
use crate::metrics::RunReport;
use crate::model::{KvCache, ShardedModel, StageInput};
use crate::runtime::Engine;
use crate::sampling::argmax;
use crate::spec::Policy;
use crate::workload::{dataset, DatasetProfile, Request, WorkloadGen};

/// One system's outcome on a workload.
#[derive(Debug, Clone)]
pub struct SystemRun {
    pub policy: Policy,
    pub report: RunReport,
    pub outputs: Vec<Vec<i32>>,
    pub accuracy: f64,
}

/// Harness over one engine + dataset.
pub struct Harness {
    pub engine: Rc<Engine>,
    pub profile: DatasetProfile,
    pub requests: Vec<Request>,
    /// Unsharded target model used for teacher-forced scoring.
    scorer: RefCell<ShardedModel>,
    /// GTA of a pure target sample at the workload temperature.
    pub base_accuracy: f64,
}

impl Harness {
    /// Build the harness: generate requests, run the Base Acc reference.
    pub fn new(
        engine: Rc<Engine>,
        dataset_name: &str,
        n_requests: usize,
        max_new_tokens: usize,
        seed: u64,
    ) -> Result<Harness> {
        let profile = dataset(dataset_name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset '{dataset_name}'"))?;
        let vocab = engine.manifest().model.vocab;
        let mut gen = WorkloadGen::new(profile.clone(), vocab, seed);
        let mut requests = gen.batch(n_requests);
        for r in &mut requests {
            r.max_new_tokens = max_new_tokens.min(r.max_new_tokens);
        }

        // Scorer: the monolithic (1-shard) target model.
        let scorer = ShardedModel::new(engine.clone(), 1, profile.draft_variant)?;

        let mut h = Harness {
            engine: engine.clone(),
            profile: profile.clone(),
            requests,
            scorer: RefCell::new(scorer),
            base_accuracy: 0.0,
        };

        // Base Acc: a pure target sample at the workload temperature.
        let base_cfg = reference_config(
            engine.manifest().dir.to_str().unwrap(),
            &profile,
            profile.temp,
            seed ^ 0xBA5E,
        );
        let base_outputs = run_outputs(&engine, &base_cfg, &h.requests)?;
        h.base_accuracy = h.score_outputs(&base_outputs)?;
        Ok(h)
    }

    /// Run one policy configuration on the shared requests.
    pub fn run(&self, mut cfg: DeployConfig, policy: Policy) -> Result<SystemRun> {
        cfg.decode.policy = policy;
        cfg.dataset = self.profile.name.to_string();
        if cfg.draft_variant.is_empty() {
            cfg.draft_variant = self.profile.draft_variant.to_string();
        }
        let mut coord = Coordinator::with_engine(self.engine.clone(), cfg)?;
        // Pre-compile everything so measured stage times are compile-free.
        coord.warmup()?;
        let (mut report, results) = coord.run_workload(self.requests.clone())?;
        let outputs: Vec<Vec<i32>> = results.into_iter().map(|r| r.tokens).collect();
        let accuracy = self.score_outputs(&outputs)?;
        report.accuracy = accuracy;
        Ok(SystemRun { policy, report, outputs, accuracy })
    }

    /// Teacher-forced greedy agreement of outputs with the target model.
    pub fn score_outputs(&self, outputs: &[Vec<i32>]) -> Result<f64> {
        let mut hits = 0usize;
        let mut total = 0usize;
        for (req, out) in self.requests.iter().zip(outputs) {
            let (h, t) = self.score_one(&req.prompt, out)?;
            hits += h;
            total += t;
        }
        Ok(if total == 0 { 0.0 } else { hits as f64 / total as f64 })
    }

    /// Score one sequence: fraction of generated tokens equal to the
    /// target argmax in their own (teacher-forced) context.
    fn score_one(&self, prompt: &[i32], output: &[i32]) -> Result<(usize, usize)> {
        let m = self.engine.manifest().model;
        let scorer = self.scorer.borrow_mut();
        let stage = &scorer.stages[0]; // single 'full' stage
        let [l, s, hd, dd] = scorer.stage_dims()[0];
        let mut cache = KvCache::new(l, s, hd, dd);

        let mut seq: Vec<i32> = prompt.to_vec();
        seq.extend_from_slice(output);
        let plen = prompt.len();

        // Pass 1: prefill window over the first min(64, len) tokens.
        let w = m.prefill_window;
        let mut padded = seq.clone();
        padded.truncate(w);
        padded.resize(w, 0);
        let (out0, _) = stage.run(w, &StageInput::Tokens(&padded), &mut cache, 0)?;
        let mut hits = 0;
        let mut total = 0;
        // Row j of the prefill output predicts position j+1: score the
        // generated positions covered by the window.
        for p in plen..seq.len().min(w) {
            let row = out0.row(p - 1);
            total += 1;
            if argmax(row) as i32 == seq[p] {
                hits += 1;
            }
        }
        // W=1 steps for positions beyond the prefill window: feeding
        // seq[p-1] at pos p-1 yields the prediction for position p.
        for p in w..seq.len() {
            let step = [seq[p - 1]];
            let (o, _) = stage.run(1, &StageInput::Tokens(&step), &mut cache, p - 1)?;
            if p >= plen {
                total += 1;
                if argmax(o.row(0)) as i32 == seq[p] {
                    hits += 1;
                }
            }
        }
        Ok((hits, total))
    }

    /// Default deployment for this harness's dataset.
    pub fn deploy(&self, n_nodes: usize, link_ms: f64, max_batch: usize) -> DeployConfig {
        let mut cfg = DeployConfig {
            n_nodes,
            link_ms,
            max_batch,
            dataset: self.profile.name.to_string(),
            draft_variant: self.profile.draft_variant.to_string(),
            ..Default::default()
        };
        cfg.decode.temp = self.profile.temp;
        cfg.artifacts_dir = self.engine.manifest().dir.to_string_lossy().into_owned();
        cfg
    }
}

fn reference_config(
    artifacts_dir: &str,
    profile: &DatasetProfile,
    temp: f32,
    seed: u64,
) -> DeployConfig {
    let mut cfg = DeployConfig {
        artifacts_dir: artifacts_dir.to_string(),
        n_nodes: 2,       // smallest pipeline; token stream is latency-free
        link_ms: 0.0,
        max_batch: 1,
        dataset: profile.name.to_string(),
        draft_variant: profile.draft_variant.to_string(),
        seed,
        ..Default::default()
    };
    cfg.decode.policy = Policy::Autoregressive;
    cfg.decode.temp = temp;
    cfg.decode.seed = seed;
    cfg
}

fn run_outputs(
    engine: &Rc<Engine>,
    cfg: &DeployConfig,
    requests: &[Request],
) -> Result<Vec<Vec<i32>>> {
    let mut coord = Coordinator::with_engine(engine.clone(), cfg.clone())?;
    let (_, results) = coord.run_workload(requests.to_vec())?;
    Ok(results.into_iter().map(|r| r.tokens).collect())
}

