//! Trace exporters and schema validators.
//!
//! Two formats, both written by `dsd serve --trace <path>`:
//!
//! * **Chrome/Perfetto `trace.json`** — the classic trace-event format
//!   (`{"traceEvents": [...]}`), loadable at <https://ui.perfetto.dev>
//!   or `chrome://tracing`. One track per pipeline node, per link, and
//!   per sequence: node/link tracks show the physical occupancy
//!   timeline (the paper's `(N−1)·t1` is literally visible as the
//!   stair of link spans); sequence tracks show the semantic round →
//!   draft/pre-draft/verify nesting with commit/decision instants.
//! * **Per-round JSONL** — one self-contained JSON object per round
//!   (timings, prediction, drift, acceptance), the grep/pandas-friendly
//!   twin of the Perfetto view.
//!
//! The validators ([`validate_perfetto`], [`validate_jsonl`]) are the
//! schema checks CI runs against emitted traces: every `ph` is one of
//! `B`/`E`/`M`/`i`, per-track timestamps are monotone, begin/end pairs
//! balance, and each JSONL line's `drift_ns` is consistent with its
//! `round_ns`/`predicted_ns`. `serve` self-validates right after
//! writing, so a malformed trace is a hard error, not a silent
//! artifact. (Span-level containment — link spans inside their round
//! span — is checked on the raw events by
//! [`super::drift::validate_spans`].)
//!
//! Exporting allocates freely (strings, sort buffers) — it runs once
//! at shutdown, outside the zero-allocation round loop.

use std::collections::{BTreeMap, BTreeSet};
use std::io;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Result};

use super::{SpanEvent, SpanKind, Track};
use crate::util::json::{parse, Value};

/// Perfetto (pid, tid) for a track: pid 1 is the cluster (nodes, then
/// links offset by 1000), pid 2 the sequences.
fn track_pid_tid(t: Track) -> (i64, i64) {
    match t {
        Track::Node(i) => (1, i as i64),
        Track::Link(i) => (1, 1000 + i as i64),
        Track::Seq(s) => (2, s as i64),
    }
}

fn track_name(t: Track) -> String {
    match t {
        Track::Node(i) => format!("node {i}"),
        Track::Link(i) => format!("link {i}"),
        Track::Seq(s) => format!("seq {s}"),
    }
}

/// Trace-event timestamps are microseconds; ours are ns.
fn us(ns: u64) -> Value {
    Value::from(ns as f64 / 1000.0)
}

fn tau_of_bits(bits: u64) -> f64 {
    f32::from_bits(bits as u32) as f64
}

/// The kind-specific argument payload (see [`SpanKind`]'s table).
fn span_args(ev: &SpanEvent) -> Value {
    let mut pairs: Vec<(&str, Value)> = vec![
        ("seq", (ev.key.seq as u64).into()),
        ("round", (ev.key.round as u64).into()),
        ("group", (ev.key.group as u64).into()),
    ];
    match ev.kind {
        SpanKind::Round => {
            pairs.push(("gamma", ev.a.into()));
            pairs.push(("predicted_ns", ev.b.into()));
        }
        SpanKind::Decision => {
            pairs.push(("gamma", ev.a.into()));
            pairs.push(("predicted_ns", ev.b.into()));
            pairs.push(("tau", tau_of_bits(ev.c).into()));
        }
        SpanKind::Draft => {
            pairs.push(("steps", ev.a.into()));
            pairs.push(("reused", ev.b.into()));
            pairs.push(("wasted", ev.c.into()));
        }
        SpanKind::PreDraft => {
            pairs.push(("tokens", ev.a.into()));
            pairs.push(("overlap_ns", ev.b.into()));
        }
        SpanKind::NodeCompute => pairs.push(("window", ev.a.into())),
        SpanKind::LinkBusy => {
            pairs.push(("bytes", ev.a.into()));
            pairs.push(("base_ns", ev.b.into()));
            // the hop's t1 + bytes/bw decomposition: dur − t1 is the
            // serialization (+queue-free occupancy) term
            pairs.push(("serialize_ns", ev.dur.saturating_sub(ev.b).into()));
        }
        SpanKind::Verify => pairs.push(("window", ev.a.into())),
        SpanKind::Commit => {
            pairs.push(("committed", ev.a.into()));
            pairs.push(("accepted", ev.b.into()));
        }
    }
    Value::obj(&pairs)
}

/// Build the Chrome trace-event JSON for a batch of span events.
pub fn perfetto_value(events: &[SpanEvent]) -> Value {
    let mut out: Vec<Value> = Vec::new();
    let tracks: BTreeSet<Track> = events.iter().map(|e| e.track).collect();
    let pids: BTreeSet<i64> = tracks.iter().map(|t| track_pid_tid(*t).0).collect();
    for pid in &pids {
        let name = if *pid == 1 { "cluster" } else { "sequences" };
        out.push(Value::obj(&[
            ("ph", "M".into()),
            ("name", "process_name".into()),
            ("pid", (*pid).into()),
            ("tid", 0i64.into()),
            ("args", Value::obj(&[("name", name.into())])),
        ]));
    }
    for t in &tracks {
        let (pid, tid) = track_pid_tid(*t);
        out.push(Value::obj(&[
            ("ph", "M".into()),
            ("name", "thread_name".into()),
            ("pid", pid.into()),
            ("tid", tid.into()),
            ("args", Value::obj(&[("name", track_name(*t).into())])),
        ]));
    }

    // Per track, emit properly nested B/E pairs (instants ride along as
    // `ph:"i"`). Spans on one track either are disjoint or nest (node
    // and link tracks serialize on busy-until; a sequence's round span
    // contains its draft/pre-draft/verify), so a begin-sorted sweep
    // with an end stack yields a balanced, monotone stream.
    let mut per_track: BTreeMap<(i64, i64), Vec<&SpanEvent>> = BTreeMap::new();
    for ev in events {
        per_track.entry(track_pid_tid(ev.track)).or_default().push(ev);
    }
    for ((pid, tid), mut evs) in per_track {
        evs.sort_by_key(|e| (e.t0, std::cmp::Reverse(e.end())));
        // stack of (end_ns, name) for open spans
        type OpenStack = Vec<(u64, &'static str)>;
        let mut open: OpenStack = Vec::new();
        let close_through = |open: &mut OpenStack, out: &mut Vec<Value>, t: u64, strict: bool| {
            while let Some(&(end, name)) = open.last() {
                if end < t || (!strict && end == t) {
                    out.push(Value::obj(&[
                        ("ph", "E".into()),
                        ("name", name.into()),
                        ("ts", us(end)),
                        ("pid", pid.into()),
                        ("tid", tid.into()),
                    ]));
                    open.pop();
                } else {
                    break;
                }
            }
        };
        for ev in evs {
            if ev.kind.is_instant() {
                // strict close: an instant at a span's exact end stays inside it
                close_through(&mut open, &mut out, ev.t0, true);
                out.push(Value::obj(&[
                    ("ph", "i".into()),
                    ("s", "t".into()),
                    ("name", ev.kind.name().into()),
                    ("ts", us(ev.t0)),
                    ("pid", pid.into()),
                    ("tid", tid.into()),
                    ("args", span_args(ev)),
                ]));
            } else {
                close_through(&mut open, &mut out, ev.t0, false);
                out.push(Value::obj(&[
                    ("ph", "B".into()),
                    ("cat", "dsd".into()),
                    ("name", ev.kind.name().into()),
                    ("ts", us(ev.t0)),
                    ("pid", pid.into()),
                    ("tid", tid.into()),
                    ("args", span_args(ev)),
                ]));
                open.push((ev.end(), ev.kind.name()));
            }
        }
        close_through(&mut open, &mut out, u64::MAX, false);
    }

    Value::obj(&[("traceEvents", Value::from(out)), ("displayTimeUnit", "ms".into())])
}

/// Write the Perfetto trace to `path`.
pub fn write_perfetto(path: &Path, events: &[SpanEvent]) -> io::Result<()> {
    std::fs::write(path, format!("{}\n", perfetto_value(events)))
}

/// One aggregated round for the JSONL view.
#[derive(Debug, Clone, Copy, Default)]
struct RoundAgg {
    group: u32,
    start: u64,
    round_ns: u64,
    predicted_ns: u64,
    gamma: u64,
    tau_bits: u64,
    draft_ns: u64,
    draft_steps: u64,
    pre_draft_ns: u64,
    overlap_ns: u64,
    verify_ns: u64,
    committed: u64,
    accepted: u64,
    link_ns: u64,
    link_bytes: u64,
    link_hops: u64,
    has_round: bool,
}

fn aggregate(events: &[SpanEvent]) -> BTreeMap<(u32, u32), RoundAgg> {
    let mut rounds: BTreeMap<(u32, u32), RoundAgg> = BTreeMap::new();
    for ev in events {
        let agg = rounds.entry((ev.key.seq, ev.key.round)).or_default();
        match ev.kind {
            SpanKind::Round => {
                agg.group = ev.key.group;
                agg.start = ev.t0;
                agg.round_ns = ev.dur;
                agg.gamma = ev.a;
                agg.predicted_ns = ev.b;
                agg.has_round = true;
            }
            SpanKind::Decision => agg.tau_bits = ev.c,
            SpanKind::Draft => {
                agg.draft_ns += ev.dur;
                agg.draft_steps += ev.a;
            }
            SpanKind::PreDraft => {
                agg.pre_draft_ns += ev.dur;
                agg.overlap_ns += ev.b;
            }
            SpanKind::Verify => agg.verify_ns += ev.dur,
            SpanKind::Commit => {
                agg.committed = ev.a;
                agg.accepted = ev.b;
            }
            SpanKind::LinkBusy => {
                agg.link_ns += ev.dur;
                agg.link_bytes += ev.a;
                agg.link_hops += 1;
            }
            SpanKind::NodeCompute => {}
        }
    }
    // rounds truncated by the ring (no Round span retained) are dropped
    rounds.retain(|_, a| a.has_round);
    rounds
}

fn drift_ns(agg: &RoundAgg) -> u64 {
    if agg.predicted_ns > 0 {
        agg.round_ns.abs_diff(agg.predicted_ns)
    } else {
        0
    }
}

/// Render the per-round JSONL (one JSON object per line, rounds in
/// (seq, round) order).
pub fn jsonl_string(events: &[SpanEvent]) -> String {
    let mut out = String::new();
    for ((seq, round), agg) in aggregate(events) {
        let line = Value::obj(&[
            ("seq", (seq as u64).into()),
            ("round", (round as u64).into()),
            ("group", (agg.group as u64).into()),
            ("start_ns", agg.start.into()),
            ("round_ns", agg.round_ns.into()),
            ("predicted_ns", agg.predicted_ns.into()),
            ("drift_ns", drift_ns(&agg).into()),
            ("gamma", agg.gamma.into()),
            ("tau", tau_of_bits(agg.tau_bits).into()),
            ("draft_ns", agg.draft_ns.into()),
            ("draft_steps", agg.draft_steps.into()),
            ("pre_draft_ns", agg.pre_draft_ns.into()),
            ("overlap_ns", agg.overlap_ns.into()),
            ("verify_ns", agg.verify_ns.into()),
            ("committed", agg.committed.into()),
            ("accepted", agg.accepted.into()),
            ("link_ns", agg.link_ns.into()),
            ("link_bytes", agg.link_bytes.into()),
            ("link_hops", agg.link_hops.into()),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Write the per-round JSONL to `path`.
pub fn write_jsonl(path: &Path, events: &[SpanEvent]) -> io::Result<()> {
    std::fs::write(path, jsonl_string(events))
}

/// Schema check for an emitted Perfetto trace: parses, every event's
/// `ph` is `B`/`E`/`M`/`i`, per-track timestamps are monotone
/// non-decreasing, and begin/end pairs balance. Returns the number of
/// balanced B/E pairs.
pub fn validate_perfetto(text: &str) -> Result<usize> {
    let v = parse(text.trim())?;
    let evs = v
        .get("traceEvents")?
        .as_array()
        .ok_or_else(|| anyhow!("traceEvents is not an array"))?;
    let mut open: BTreeMap<(i64, i64), Vec<String>> = BTreeMap::new();
    let mut last_ts: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    let mut pairs = 0usize;
    for e in evs {
        let ph = e.str_field("ph")?;
        if ph == "M" {
            continue;
        }
        let pid = e.get("pid")?.as_i64().ok_or_else(|| anyhow!("pid is not an integer"))?;
        let tid = e.get("tid")?.as_i64().ok_or_else(|| anyhow!("tid is not an integer"))?;
        let ts = e.f64_field("ts")?;
        let track = (pid, tid);
        if let Some(prev) = last_ts.get(&track) {
            ensure!(
                ts >= *prev,
                "timestamps not monotone on track pid={pid} tid={tid}: {ts} after {prev}"
            );
        }
        last_ts.insert(track, ts);
        match ph {
            "B" => open.entry(track).or_default().push(e.str_field("name")?.to_string()),
            "E" => {
                let name = e.str_field("name")?;
                let st = open.entry(track).or_default();
                let Some(top) = st.pop() else {
                    bail!("unbalanced E '{name}' on track pid={pid} tid={tid}");
                };
                ensure!(
                    top == name,
                    "mismatched E on track pid={pid} tid={tid}: closed '{name}', open '{top}'"
                );
                pairs += 1;
            }
            "i" => {}
            other => bail!("unexpected ph '{other}'"),
        }
    }
    for (track, st) in open {
        ensure!(st.is_empty(), "unclosed span(s) {st:?} on track {track:?}");
    }
    ensure!(pairs > 0, "trace has no begin/end spans");
    Ok(pairs)
}

/// Schema check for the per-round JSONL: every line parses, carries
/// the required fields, and its `drift_ns` equals
/// `|round_ns − predicted_ns|` (0 when no prediction was recorded).
/// Returns the number of rounds.
pub fn validate_jsonl(text: &str) -> Result<usize> {
    let mut rounds = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = parse(line).map_err(|e| anyhow!("line {}: {e}", i + 1))?;
        for field in ["seq", "round", "group", "gamma", "committed"] {
            v.usize_field(field).map_err(|e| anyhow!("line {}: {e}", i + 1))?;
        }
        let round_ns = v.usize_field("round_ns")? as u64;
        let predicted = v.usize_field("predicted_ns")? as u64;
        let drift = v.usize_field("drift_ns")? as u64;
        let expect = if predicted > 0 { round_ns.abs_diff(predicted) } else { 0 };
        ensure!(
            drift == expect,
            "line {}: drift_ns {drift} inconsistent with |{round_ns} - {predicted}|",
            i + 1
        );
        rounds += 1;
    }
    ensure!(rounds > 0, "JSONL trace has no rounds");
    Ok(rounds)
}

#[cfg(test)]
mod tests {
    use super::super::{SpanEvent, SpanKind, Track, TraceKey};
    use super::*;

    fn keyed(mut ev: SpanEvent, key: TraceKey) -> SpanEvent {
        ev.key = key;
        ev
    }

    /// One synthetic round: draft → node/link activity → verify,
    /// wrapped in a round span with a decision/commit instant.
    fn round_events(seq: u32, round: u32, t0: u64) -> Vec<SpanEvent> {
        let k = TraceKey::new(seq, round, round + 1);
        vec![
            keyed(
                SpanEvent::new(SpanKind::Round, Track::Seq(seq), t0, 1000).args(4, 990, 0),
                k,
            ),
            keyed(SpanEvent::new(SpanKind::Decision, Track::Seq(seq), t0, 0).args(4, 990, 0), k),
            keyed(SpanEvent::new(SpanKind::Draft, Track::Seq(seq), t0, 100).args(5, 0, 0), k),
            keyed(
                SpanEvent::new(SpanKind::NodeCompute, Track::Node(0), t0, 100).args(5, 0, 0),
                k,
            ),
            keyed(
                SpanEvent::new(SpanKind::LinkBusy, Track::Link(0), t0 + 100, 300).args(640, 250, 0),
                k,
            ),
            keyed(
                SpanEvent::new(SpanKind::Verify, Track::Seq(seq), t0 + 900, 100).args(4, 0, 0),
                k,
            ),
            keyed(
                SpanEvent::new(SpanKind::Commit, Track::Seq(seq), t0 + 1000, 0).args(3, 2, 0),
                k,
            ),
        ]
    }

    #[test]
    fn perfetto_roundtrip_validates() {
        let mut evs = round_events(0, 0, 0);
        evs.extend(round_events(0, 1, 1000));
        evs.extend(round_events(1, 0, 500));
        let text = format!("{}", perfetto_value(&evs));
        let pairs = validate_perfetto(&text).unwrap();
        // 3 rounds × (round + draft + verify + compute + link) spans
        assert_eq!(pairs, 15);
        assert!(text.contains("\"thread_name\""));
        assert!(text.contains("node 0"));
        assert!(text.contains("link 0"));
        assert!(text.contains("seq 1"));
    }

    #[test]
    fn jsonl_roundtrip_validates() {
        let mut evs = round_events(0, 0, 0);
        evs.extend(round_events(0, 1, 1000));
        let text = jsonl_string(&evs);
        assert_eq!(validate_jsonl(&text).unwrap(), 2);
        let first = parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.usize_field("round_ns").unwrap(), 1000);
        assert_eq!(first.usize_field("predicted_ns").unwrap(), 990);
        assert_eq!(first.usize_field("drift_ns").unwrap(), 10);
        assert_eq!(first.usize_field("committed").unwrap(), 3);
        assert_eq!(first.usize_field("link_hops").unwrap(), 1);
    }

    #[test]
    fn validator_rejects_unbalanced_spans() {
        let text = r#"{"traceEvents":[
            {"ph":"B","name":"round","ts":0.0,"pid":2,"tid":0},
            {"ph":"B","name":"draft","ts":1.0,"pid":2,"tid":0},
            {"ph":"E","name":"draft","ts":2.0,"pid":2,"tid":0}
        ]}"#;
        let err = validate_perfetto(text).unwrap_err().to_string();
        assert!(err.contains("unclosed"), "{err}");
    }

    #[test]
    fn validator_rejects_nonmonotone_timestamps() {
        let text = r#"{"traceEvents":[
            {"ph":"B","name":"round","ts":5.0,"pid":2,"tid":0},
            {"ph":"E","name":"round","ts":1.0,"pid":2,"tid":0}
        ]}"#;
        let err = validate_perfetto(text).unwrap_err().to_string();
        assert!(err.contains("monotone"), "{err}");
    }

    #[test]
    fn validator_rejects_inconsistent_drift() {
        let good = jsonl_string(&round_events(0, 0, 0));
        let bad = good.replace("\"drift_ns\":10", "\"drift_ns\":11");
        assert_ne!(good, bad, "fixture must actually tamper the line");
        assert!(validate_jsonl(&bad).is_err());
    }

    #[test]
    fn truncated_rounds_are_dropped_from_jsonl() {
        // a ring that lost round 0's Round span keeps only round 1
        let mut evs = round_events(0, 0, 0);
        evs.remove(0);
        evs.extend(round_events(0, 1, 1000));
        assert_eq!(validate_jsonl(&jsonl_string(&evs)).unwrap(), 1);
    }
}
