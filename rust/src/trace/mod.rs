//! Round-trace observability: zero-allocation span tracing for the
//! decode hot path.
//!
//! The paper's claim is a statement about *where round time goes* —
//! Eq. 5's `(N−1)·t1·(k−1)/k` saving lives on the comm/compute
//! timeline — so the repo needs more than aggregates: per-round,
//! per-hop spans showing draft → wire → verify → commit, from both the
//! discrete-event simulator (sim time) and the socket transport (wall
//! time).
//!
//! Design constraints, in order:
//!
//! 1. **Zero allocations in steady state** (the PR 5 invariant): the
//!    tracer is a preallocated ring buffer of fixed-size POD
//!    [`SpanEvent`]s. Recording is a bounds-checked store; when the
//!    ring is full the oldest event is overwritten (and counted in
//!    [`RingTracer::dropped`]), never reallocated. Pinned by the
//!    tracing-enabled case in `tests/alloc_budget.rs`.
//! 2. **Free when off**: producers hold an `Option<RingTracer>` (the
//!    simulator) or a `&mut dyn TraceSink` (the socket transport);
//!    the disabled impl ([`NoopSink`]) is a unit struct whose methods
//!    compile to nothing.
//! 3. **Keyed spans**: every event carries a [`TraceKey`] — which
//!    sequence, which round of that sequence, and which fused group
//!    pass — stamped by the sink from its current key so hot-path
//!    call sites don't thread the key through every helper.
//!
//! Exporters ([`export`]) turn the ring into a Chrome/Perfetto
//! `trace.json` (one track per node, link, and sequence) and a
//! per-round JSONL log; the drift auditor ([`drift`]) compares each
//! round's cost-model prediction against the traced actual —
//! extending the PR 3 property (the closed form matches
//! `PipelineSim`) from the formula to recorded executions.

pub mod drift;
pub mod export;

use crate::cluster::clock::Nanos;

/// Identifies what a span belongs to: the sequence, that sequence's
/// round counter, and the fused-group pass id (`PipelineSim`'s
/// `sync_rounds` serial — members of one fused round share it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceKey {
    pub seq: u32,
    pub round: u32,
    pub group: u32,
}

impl TraceKey {
    pub fn new(seq: u32, round: u32, group: u32) -> Self {
        TraceKey { seq, round, group }
    }
}

/// Which timeline row a span occupies in the exported trace: a
/// pipeline node's compute timeline, a link's occupancy timeline, or a
/// sequence's semantic round timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Track {
    Node(u16),
    Link(u16),
    Seq(u32),
}

/// Span kinds and the meaning of their `a`/`b`/`c` payload words:
///
/// | kind          | a                | b                    | c          |
/// |---------------|------------------|----------------------|------------|
/// | `Round`       | γ                | predicted round ns   | —          |
/// | `Decision`    | γ                | predicted round ns   | τ f32 bits |
/// | `Draft`       | draft steps      | reused (0/1)         | wasted     |
/// | `PreDraft`    | pre-draft tokens | overlap ns           | —          |
/// | `NodeCompute` | window tokens    | —                    | —          |
/// | `LinkBusy`    | payload bytes    | link base ns (`t1`)  | —          |
/// | `Verify`      | window nodes     | —                    | —          |
/// | `Commit`      | committed        | accepted             | —          |
///
/// `Decision` and `Commit` are instants (`dur == 0` by convention);
/// the rest are durations. A `LinkBusy` span's serialization term is
/// `dur − b` — the `t1 + bytes/bw` decomposition of one hop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Round,
    Decision,
    Draft,
    PreDraft,
    NodeCompute,
    LinkBusy,
    Verify,
    Commit,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Round => "round",
            SpanKind::Decision => "decision",
            SpanKind::Draft => "draft",
            SpanKind::PreDraft => "pre_draft",
            SpanKind::NodeCompute => "compute",
            SpanKind::LinkBusy => "link",
            SpanKind::Verify => "verify",
            SpanKind::Commit => "commit",
        }
    }

    /// Instant markers (exported as Perfetto `ph:"i"`, not B/E pairs).
    pub fn is_instant(self) -> bool {
        matches!(self, SpanKind::Decision | SpanKind::Commit)
    }
}

/// One fixed-size POD trace event. `Copy` by design: recording one is
/// a store into the preallocated ring, nothing more.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub track: Track,
    /// Stamped by the sink from its current key (see
    /// [`TraceSink::set_key`]); the value passed in is ignored.
    pub key: TraceKey,
    pub t0: Nanos,
    pub dur: Nanos,
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

impl SpanEvent {
    pub fn new(kind: SpanKind, track: Track, t0: Nanos, dur: Nanos) -> Self {
        SpanEvent { kind, track, key: TraceKey::default(), t0, dur, a: 0, b: 0, c: 0 }
    }

    /// Attach the kind-specific payload words (see [`SpanKind`]).
    pub fn args(mut self, a: u64, b: u64, c: u64) -> Self {
        self.a = a;
        self.b = b;
        self.c = c;
        self
    }

    pub fn end(&self) -> Nanos {
        self.t0 + self.dur
    }
}

/// Where producers send spans. The disabled impl ([`NoopSink`])
/// compiles to no-ops; the enabled impl ([`RingTracer`]) stores into
/// a preallocated ring.
pub trait TraceSink {
    /// Whether recording is live — producers may skip building events
    /// entirely when this is false.
    fn enabled(&self) -> bool;
    /// Set the (sequence, round, group) stamped onto every following
    /// [`TraceSink::record`] until the next `set_key`.
    fn set_key(&mut self, key: TraceKey);
    /// Record one span (the sink overwrites `ev.key` with its current
    /// key).
    fn record(&mut self, ev: SpanEvent);
}

/// The disabled sink: every method is an empty body the optimizer
/// erases.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn enabled(&self) -> bool {
        false
    }
    fn set_key(&mut self, _key: TraceKey) {}
    fn record(&mut self, _ev: SpanEvent) {}
}

/// The enabled sink: a ring buffer preallocated at construction.
/// Recording never allocates — once full, the oldest event is
/// overwritten and counted in [`RingTracer::dropped`].
#[derive(Debug, Clone)]
pub struct RingTracer {
    buf: Vec<SpanEvent>,
    /// Oldest event's index once the ring has wrapped (0 before).
    head: usize,
    dropped: u64,
    key: TraceKey,
}

impl RingTracer {
    /// Preallocate a ring of `cap` events (~64 B each; 64 Ki events is
    /// a few MB and covers tens of thousands of rounds).
    pub fn with_capacity(cap: usize) -> Self {
        RingTracer {
            buf: Vec::with_capacity(cap.max(1)),
            head: 0,
            dropped: 0,
            key: TraceKey::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten after the ring wrapped.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn key(&self) -> TraceKey {
        self.key
    }

    /// Retained events, oldest first. Allocation-free iteration.
    pub fn events(&self) -> impl Iterator<Item = &SpanEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    /// Retained events, oldest first, as an owned vec (export-time
    /// convenience — allocates, so not for the hot path).
    pub fn to_vec(&self) -> Vec<SpanEvent> {
        self.events().copied().collect()
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.dropped = 0;
    }
}

impl TraceSink for RingTracer {
    fn enabled(&self) -> bool {
        true
    }

    fn set_key(&mut self, key: TraceKey) {
        self.key = key;
    }

    fn record(&mut self, mut ev: SpanEvent) {
        ev.key = self.key;
        let cap = self.buf.capacity();
        if self.buf.len() < cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head += 1;
            if self.head == cap {
                self.head = 0;
            }
            self.dropped += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: SpanKind, t0: Nanos) -> SpanEvent {
        SpanEvent::new(kind, Track::Node(0), t0, 10)
    }

    #[test]
    fn ring_stamps_current_key() {
        let mut t = RingTracer::with_capacity(8);
        t.set_key(TraceKey::new(3, 7, 11));
        t.record(ev(SpanKind::Draft, 0).args(5, 0, 0));
        let e = t.events().next().unwrap();
        assert_eq!(e.key, TraceKey::new(3, 7, 11));
        assert_eq!(e.a, 5);
        assert_eq!(e.kind.name(), "draft");
    }

    #[test]
    fn ring_wraps_without_growing() {
        let mut t = RingTracer::with_capacity(4);
        let cap = t.capacity();
        for i in 0..10u64 {
            t.record(ev(SpanKind::NodeCompute, i).args(i, 0, 0));
        }
        assert_eq!(t.capacity(), cap, "ring must never grow");
        assert_eq!(t.len(), cap);
        assert_eq!(t.dropped(), 10 - cap as u64);
        // oldest-first iteration across the wrap point
        let order: Vec<u64> = t.events().map(|e| e.a).collect();
        let expect: Vec<u64> = (10 - cap as u64..10).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn clear_resets_ring() {
        let mut t = RingTracer::with_capacity(2);
        for i in 0..5 {
            t.record(ev(SpanKind::Verify, i));
        }
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
        t.record(ev(SpanKind::Verify, 9));
        assert_eq!(t.events().next().unwrap().t0, 9);
    }

    #[test]
    fn noop_sink_is_inert() {
        let mut s = NoopSink;
        assert!(!s.enabled());
        s.set_key(TraceKey::new(1, 2, 3));
        s.record(ev(SpanKind::Round, 0));
    }

    #[test]
    fn instant_kinds() {
        assert!(SpanKind::Decision.is_instant());
        assert!(SpanKind::Commit.is_instant());
        assert!(!SpanKind::Round.is_instant());
        assert!(!SpanKind::LinkBusy.is_instant());
    }
}
