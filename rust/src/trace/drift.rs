//! Cost-model drift auditing over recorded traces.
//!
//! PR 3 pinned the closed form: `control::cost::CostModel::
//! round_time_ns` matches a fresh `PipelineSim` charging the same
//! round (`tests/control_props.rs`). This module extends that property
//! from the formula to *recorded executions*: every `Round` span
//! carries the controller's predicted round time (`b`) next to the
//! traced actual (`dur`), so auditing a trace answers "did the model
//! the controller optimizes against track what the cluster actually
//! did?" — per round, not in expectation.
//!
//! On the engine-free sim path with solo (unfused) rounds the answer
//! must be **exactly 0 ns**: the oracle's links are jitter-free, its
//! calibration constants are the model's own, and steady-state rounds
//! see no queueing — asserted by `tests/trace_schema.rs` and the CI
//! serve-trace smoke. Fused and multi-sequence runs drift legitimately
//! (queueing on shared links, fused comm amortization priced per
//! group), and engine-backed rounds drift by the gap between measured
//! kernel time and the calibration constants — that histogram is the
//! calibration signal for the real-transport direction (ROADMAP).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::{SpanEvent, SpanKind};
use crate::cluster::clock::Nanos;

/// Aggregate prediction error over the `Round` spans of a trace.
#[derive(Debug, Clone, Copy, Default)]
pub struct DriftReport {
    /// Rounds audited (a prediction was recorded — AR/tree rounds and
    /// rounds without a controller decision are skipped).
    pub rounds: usize,
    /// Rounds whose predicted and actual times match exactly.
    pub exact: usize,
    pub max_ns: Nanos,
    pub sum_ns: u128,
}

impl DriftReport {
    pub fn mean_ns(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.rounds as f64
        }
    }

    /// True when every audited round matched its prediction exactly —
    /// the engine-free solo-path invariant.
    pub fn is_exact(&self) -> bool {
        self.rounds > 0 && self.exact == self.rounds
    }
}

/// Audit a trace: per `Round` span with a recorded prediction,
/// accumulate `|actual − predicted|`.
pub fn audit<'a>(events: impl IntoIterator<Item = &'a SpanEvent>) -> DriftReport {
    let mut r = DriftReport::default();
    for ev in events {
        if ev.kind == SpanKind::Round && ev.b > 0 {
            let d = ev.dur.abs_diff(ev.b);
            r.rounds += 1;
            if d == 0 {
                r.exact += 1;
            }
            r.max_ns = r.max_ns.max(d);
            r.sum_ns += d as u128;
        }
    }
    r
}

/// Structural containment check on raw span events: everything keyed
/// to a round — link occupancy, node compute, draft, pre-draft,
/// verify — must lie inside that round's span, and instants must fall
/// within it. Spans keyed to a round the ring no longer retains are
/// skipped (the ring drops oldest-first, so a retained child may
/// outlive its round span).
pub fn validate_spans(events: &[SpanEvent]) -> Result<()> {
    let mut rounds: BTreeMap<(u32, u32), (Nanos, Nanos)> = BTreeMap::new();
    for ev in events {
        if ev.kind == SpanKind::Round {
            rounds.insert((ev.key.seq, ev.key.round), (ev.t0, ev.end()));
        }
    }
    for ev in events {
        let Some(&(r0, r1)) = rounds.get(&(ev.key.seq, ev.key.round)) else {
            continue;
        };
        match ev.kind {
            SpanKind::Round => {}
            SpanKind::Decision | SpanKind::Commit => {
                ensure!(
                    ev.t0 >= r0 && ev.t0 <= r1,
                    "{} instant at {} outside round span [{r0}, {r1}] for {:?}",
                    ev.kind.name(),
                    ev.t0,
                    ev.key
                );
            }
            _ => {
                ensure!(
                    ev.t0 >= r0 && ev.end() <= r1,
                    "{} span [{}, {}] escapes round span [{r0}, {r1}] for {:?}",
                    ev.kind.name(),
                    ev.t0,
                    ev.end(),
                    ev.key
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{Track, TraceKey};
    use super::*;

    fn round(seq: u32, r: u32, t0: Nanos, dur: Nanos, predicted: u64) -> SpanEvent {
        let mut ev =
            SpanEvent::new(SpanKind::Round, Track::Seq(seq), t0, dur).args(4, predicted, 0);
        ev.key = TraceKey::new(seq, r, r);
        ev
    }

    fn child(seq: u32, r: u32, kind: SpanKind, t0: Nanos, dur: Nanos) -> SpanEvent {
        let mut ev = SpanEvent::new(kind, Track::Link(0), t0, dur);
        ev.key = TraceKey::new(seq, r, r);
        ev
    }

    #[test]
    fn audit_accumulates_abs_error() {
        let evs = [
            round(0, 0, 0, 1000, 1000),
            round(0, 1, 1000, 1030, 1000),
            round(0, 2, 2030, 990, 1000),
            // no prediction recorded: skipped
            round(0, 3, 3020, 500, 0),
        ];
        let r = audit(evs.iter());
        assert_eq!(r.rounds, 3);
        assert_eq!(r.exact, 1);
        assert_eq!(r.max_ns, 30);
        assert_eq!(r.sum_ns, 40);
        assert!((r.mean_ns() - 40.0 / 3.0).abs() < 1e-9);
        assert!(!r.is_exact());
    }

    #[test]
    fn exact_report_requires_all_rounds_exact() {
        let evs = [round(0, 0, 0, 1000, 1000), round(0, 1, 1000, 800, 800)];
        assert!(audit(evs.iter()).is_exact());
        assert!(!audit(std::iter::empty()).is_exact(), "empty trace is not a pass");
    }

    #[test]
    fn containment_accepts_nested_spans() {
        let evs = [
            round(0, 0, 100, 1000, 0),
            child(0, 0, SpanKind::LinkBusy, 200, 300),
            child(0, 0, SpanKind::Verify, 1000, 100),
            child(0, 0, SpanKind::Commit, 1100, 0),
            // keyed to an unretained round: skipped, not an error
            child(9, 9, SpanKind::LinkBusy, 0, 50),
        ];
        validate_spans(&evs).unwrap();
    }

    #[test]
    fn containment_rejects_escaping_link_span() {
        let evs = [round(0, 0, 100, 1000, 0), child(0, 0, SpanKind::LinkBusy, 900, 300)];
        let err = validate_spans(&evs).unwrap_err().to_string();
        assert!(err.contains("escapes"), "{err}");
    }
}
