//! `dsd` — the DSD serving launcher.
//!
//! Subcommands:
//!   serve        run a workload on the simulated decentralized cluster
//!   compare      run baseline / eagle3 / dsd on the same workload
//!   sweep        node-count sweep (quick look; full sweeps live in
//!                `cargo bench`)
//!   inspect      print manifest/artifact info
//!   init-config  write a commented deploy.toml
//!
//! Examples:
//!   dsd serve --dataset humaneval --nodes 4 --policy dsd --requests 8
//!   dsd compare --dataset gsm8k --nodes 8 --link_ms 3
//!   dsd inspect --artifacts_dir artifacts

use std::path::Path;

use anyhow::{bail, Result};

use dsd::config::DeployConfig;
use dsd::coordinator::{Coordinator, OracleConfig, OracleFleet, ShardTier, TierConfig};
use dsd::metrics::RunReport;
use dsd::spec::Policy;
use dsd::telemetry::{self, FleetMetrics};
use dsd::trace::{drift, export, RingTracer, SpanEvent};
use dsd::util::bench::write_bench_json_in;
use dsd::util::cli;
use dsd::util::json::Value;
use dsd::util::table::{fnum, Table};
use dsd::workload::{dataset, WorkloadGen};

const VALUED: &[&str] = &[
    "config", "artifacts_dir", "nodes", "n_nodes", "link_ms", "link_gbps", "jitter",
    "draft", "draft_variant", "draft_shape", "max_batch", "fuse", "max_fuse", "fuse_tokens",
    "dataset", "requests", "seed", "policy", "gamma", "temp", "tau", "lam1", "lam2", "lam3",
    "max_new_tokens", "overlap", "controller", "out", "sweep_nodes", "trace", "json",
    "metrics", "straggler_factor", "calibrate", "shards", "placement", "kv_page_tokens",
    "arrival_rps",
];

/// Span ring capacity for `--trace` (~64 B/event: a few MB, tens of
/// thousands of rounds before the ring wraps).
const TRACE_RING_CAP: usize = 1 << 16;

fn main() -> Result<()> {
    let args = cli::parse_env(VALUED)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "compare" => compare(&args),
        "sweep" => sweep(&args),
        "inspect" => inspect(&args),
        "init-config" => init_config(&args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `dsd help`)"),
    }
}

const HELP: &str = "\
dsd — Decentralized Speculative Decoding launcher

USAGE: dsd <serve|compare|sweep|inspect|init-config> [--key value ...]

Common options:
  --config FILE          layer a deploy.toml before CLI overrides
  --artifacts_dir DIR    AOT artifact directory (default: artifacts)
  --nodes N              pipeline nodes (2/4/8)         [4]
  --link_ms MS[,MS..]    per-link one-way latency; a comma list gives
                         one value per forward hop (heterogeneous chain)
  --link_gbps G          link bandwidth, 0 = infinite   [1.0]
  --dataset NAME         humaneval|gsm8k|alpaca|mtbench|cnndm
  --policy P             baseline|eagle3|dsd            [dsd]
  --gamma G              draft window                   [8]
  --draft_shape S        chain | tree:<branching>x<depth>  [chain]
  --overlap S            speculate-ahead scheduler, on|off [on]
  --controller C         static|aimd|cost-optimal       [static]
  --fuse S               fused multi-sequence rounds, on|off [on]
  --max_fuse B           max sequences per fused round  [4]
  --fuse_tokens T        token budget of one fused pass [64]
  --temp T               sampling temperature           [1.0]
  --tau T                relaxation coefficient         [0.2]
  --requests N           number of requests             [8]
  --max_batch B          KV slots / max concurrency     [8]
  --seed S               RNG seed

Observability (serve):
  --oracle               engine-free serve on the oracle sim twin (no
                         artifacts needed; drift is exactly 0 on solo
                         jitter-free rounds)
  --trace FILE           write a Chrome/Perfetto trace (open in
                         ui.perfetto.dev) plus a per-round FILE.jsonl,
                         schema-validated after writing
  --json DIR             write machine-readable BENCH_serve.json into DIR
  --metrics FILE         write a Prometheus text-exposition snapshot of
                         the fleet registry (validated after writing)
  --calibrate S          online per-link EWMA calibration feeding the
                         controller's cost model, on|off [off]
  --straggler_factor F   flag links whose hop estimate exceeds the
                         fleet median by Fx [3.0]

Serving tier (engine-free, with --oracle):
  --shards M             coordinator shards, each a full pipeline
                         replica [1]
  --placement P          least-loaded | hash (static id partition) [least-loaded]
  --kv_page_tokens T     tokens per KV page for paged admission [16]
  --arrival_rps R        open-loop arrival rate, req/s; 0 = closed
                         loop (all requests at t=0) [0]
";

fn build_config(args: &cli::Args) -> Result<DeployConfig> {
    let mut cfg = DeployConfig::default();
    if let Some(path) = args.get("config") {
        cfg.load_file(path)?;
    }
    cfg.apply_args(args)?;
    cfg.validate()?;
    Ok(cfg)
}

fn run_once(cfg: &DeployConfig) -> Result<RunReport> {
    let mut coord = Coordinator::new(cfg.clone())?;
    coord.warmup()?;
    let profile = dataset(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", cfg.dataset))?;
    let vocab = coord.engine.manifest().model.vocab;
    let mut gen = WorkloadGen::new(profile, vocab, cfg.seed);
    let requests = gen.batch(cfg.requests);
    let (report, _) = coord.run_workload(requests)?;
    Ok(report)
}

fn serve(args: &cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    let json_dir = args.get("json").map(std::path::PathBuf::from);
    let metrics_path = args.get("metrics").map(std::path::PathBuf::from);
    if args.flag("oracle") {
        if cfg.shards > 1 || cfg.arrival_rps > 0.0 {
            if trace_path.is_some() || metrics_path.is_some() {
                eprintln!(
                    "note: --trace/--metrics apply to single-shard closed-loop serves; \
                     the sharded tier reports per-shard rows instead"
                );
            }
            return serve_tier(&cfg, json_dir.as_deref());
        }
        return serve_oracle(
            &cfg,
            trace_path.as_deref(),
            json_dir.as_deref(),
            metrics_path.as_deref(),
        );
    }
    eprintln!(
        "serving {} requests of '{}' on N={} nodes (t1={}ms, policy={})...",
        cfg.requests, cfg.dataset, cfg.n_nodes, cfg.link_ms, cfg.decode.policy.name()
    );
    let mut coord = Coordinator::new(cfg.clone())?;
    coord.warmup()?;
    let profile = dataset(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", cfg.dataset))?;
    let vocab = coord.engine.manifest().model.vocab;
    let mut gen = WorkloadGen::new(profile, vocab, cfg.seed);
    let requests = gen.batch(cfg.requests);
    if trace_path.is_some() {
        coord.sim.set_tracer(RingTracer::with_capacity(TRACE_RING_CAP));
    }
    if coord.sim.metrics().is_none() {
        // Fleet registry: powers the per-node/per-link breakdown and
        // `--metrics` even when `--calibrate` didn't attach one.
        let n_links = cfg.topology().links.len();
        coord.sim.set_metrics(FleetMetrics::for_fleet(cfg.n_nodes, n_links));
    }
    let (mut report, _) = coord.run_workload(requests)?;
    let events = coord.sim.take_tracer().map(|t| t.to_vec()).unwrap_or_default();
    let fm = coord.sim.take_metrics();
    if let Some(m) = fm.as_ref() {
        report.attach_fleet(m, cfg.straggler_factor);
    }
    print_serve_report(&cfg, &report);
    write_metrics_snapshot(&cfg, fm.as_ref(), metrics_path.as_deref())?;
    write_outputs(&cfg, &report, &events, trace_path.as_deref(), json_dir.as_deref())
}

/// Engine-free serve: B oracle sequences over the shared simulated
/// pipeline — no AOT artifacts needed, so this is the CI smoke path for
/// `--trace`. With `--requests 1 --fuse off` (one sequence, solo
/// jitter-free rounds) the cost model reproduces the simulator exactly
/// and the printed drift is 0; more sequences queue on the shared
/// leader and fused groups amortize the sync, both of which the solo
/// pricing deliberately doesn't see — the drift histogram is exactly
/// that calibration-gap signal.
fn serve_oracle(
    cfg: &DeployConfig,
    trace_path: Option<&Path>,
    json_dir: Option<&Path>,
    metrics_path: Option<&Path>,
) -> Result<()> {
    let group_cap = if cfg.fuse { cfg.max_fuse.max(1) } else { 1 };
    eprintln!(
        "serving {} oracle sequences engine-free on N={} nodes (t1={}ms, fuse cap {})...",
        cfg.requests, cfg.n_nodes, cfg.link_ms, group_cap
    );
    let ocfg = OracleConfig {
        gamma: cfg.decode.gamma,
        overlap: cfg.decode.overlap,
        controller: cfg.decode.controller,
        seed: cfg.seed,
        nodes: cfg.n_nodes,
        link_ms: cfg.link_ms,
        link_ms_hops: cfg.link_ms_hops.clone(),
        calibrate: cfg.calibrate,
        fuse: group_cap,
        ..Default::default()
    };
    let batch = cfg.requests.max(1);
    let tokens_per_seq = cfg.decode.max_new_tokens;
    let mut fleet = OracleFleet::new(&ocfg, batch, &[2, 7, 1, 8])?;
    fleet.warm_capacity(tokens_per_seq + 64);
    if trace_path.is_some() {
        fleet.sim.set_tracer(RingTracer::with_capacity(TRACE_RING_CAP));
    }
    if fleet.sim.metrics().is_none() {
        let n_links = ocfg.topology().links.len();
        fleet.sim.set_metrics(FleetMetrics::for_fleet(cfg.n_nodes, n_links));
    }
    let fr = fleet.serve(tokens_per_seq, group_cap, cfg.fuse_tokens);
    let mut report = RunReport::new(format!("oracle/N{}", cfg.n_nodes));
    report.requests = batch as u64;
    report.tokens = fr.tokens;
    report.elapsed_ns = fr.finish_ns;
    report.comm_ns = fleet.sim.stats.comm_ns;
    report.compute_ns = fleet.sim.stats.compute_ns;
    report.comm_bytes = fleet.sim.stats.bytes;
    report.sync_rounds = fleet.sim.stats.sync_rounds;
    report.accept = fleet.accept_stats().clone();
    report.drift = fleet.drift().clone();
    for s in &fleet.seqs {
        report.request_latency.record(s.finish_time());
    }
    for s in 0..batch {
        // Closed loop: every sequence arrives at t=0, so TTFT is the
        // absolute time of its first committed round.
        report.ttft.record(fleet.first_commit(s));
    }
    let events = fleet.sim.take_tracer().map(|t| t.to_vec()).unwrap_or_default();
    let fm = fleet.sim.take_metrics();
    if let Some(m) = fm.as_ref() {
        report.attach_fleet(m, cfg.straggler_factor);
    }
    print_serve_report(cfg, &report);
    write_metrics_snapshot(cfg, fm.as_ref(), metrics_path)?;
    write_outputs(cfg, &report, &events, trace_path, json_dir)
}

/// Sharded serving tier (engine-free): M coordinator shards behind the
/// placement router, paged-KV admission, open-loop arrivals. This is
/// the `--shards M` / `--arrival_rps R` path; its tail-latency wins are
/// pinned by `benches/ablation_shard.rs`.
fn serve_tier(cfg: &DeployConfig, json_dir: Option<&Path>) -> Result<()> {
    let group_cap = if cfg.fuse { cfg.max_fuse.max(1) } else { 1 };
    eprintln!(
        "serving {} requests on {} shard(s) ({} placement, {} KV, N={} nodes/shard, \
         t1={}ms, arrival {} req/s)...",
        cfg.requests,
        cfg.shards,
        cfg.placement.name(),
        "paged",
        cfg.n_nodes,
        cfg.link_ms,
        cfg.arrival_rps,
    );
    let ocfg = OracleConfig {
        gamma: cfg.decode.gamma,
        overlap: cfg.decode.overlap,
        controller: cfg.decode.controller,
        seed: cfg.seed,
        nodes: cfg.n_nodes,
        link_ms: cfg.link_ms,
        link_ms_hops: cfg.link_ms_hops.clone(),
        calibrate: cfg.calibrate,
        fuse: group_cap,
        ..Default::default()
    };
    let mut tier_cfg = TierConfig::new(ocfg);
    tier_cfg.shards = cfg.shards;
    tier_cfg.placement = cfg.placement;
    tier_cfg.page_tokens = cfg.kv_page_tokens;
    tier_cfg.slots = cfg.max_batch;
    tier_cfg.slot_tokens = cfg.slot_tokens();
    tier_cfg.max_members = cfg.max_batch * 4;
    tier_cfg.group_cap = group_cap;
    tier_cfg.token_budget = cfg.fuse_tokens;
    let profile = dataset(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", cfg.dataset))?;
    let mut gen = WorkloadGen::new(profile, tier_cfg.oracle.vocab, cfg.seed);
    let requests = if cfg.arrival_rps > 0.0 {
        gen.open_loop(cfg.requests, cfg.arrival_rps, 4.0, 4)
    } else {
        gen.batch(cfg.requests)
    };
    let mut tier = ShardTier::new(tier_cfg)?;
    let tr = tier.run(&requests)?;
    let mut report = RunReport::new(format!("tier/{}x N{}", cfg.shards, cfg.n_nodes));
    report.requests = tr.requests;
    report.tokens = tr.tokens;
    report.elapsed_ns = tr.finish_ns;
    report.comm_ns = tr.shards.iter().map(|r| r.comm_ns).sum();
    report.sync_rounds = tr.shards.iter().map(|r| r.sync_rounds).sum();
    report.accept = tr.accept.clone();
    report.request_latency = tr.latency.clone();
    report.ttft = tr.ttft.clone();
    print_serve_report(cfg, &report);
    let mut t = Table::new(
        format!(
            "per-shard rows | {} placement, page {} tok",
            cfg.placement.name(),
            cfg.kv_page_tokens
        ),
        &[
            "shard", "placed", "admitted", "preempt", "readmit", "faults", "pages hwm/total",
            "peak B", "tokens", "rounds", "finish ms",
        ],
    );
    for (i, row) in tr.shards.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            row.placed.to_string(),
            row.admitted.to_string(),
            row.preempted.to_string(),
            row.readmits.to_string(),
            row.faults.to_string(),
            format!("{}/{}", row.pages_hwm, row.pages_total),
            row.peak_members.to_string(),
            row.tokens.to_string(),
            row.group_rounds.to_string(),
            fnum(row.finish_ns as f64 / 1e6, 1),
        ]);
    }
    t.print();
    write_outputs(cfg, &report, &[], None, json_dir)
}

/// `--metrics FILE`: Prometheus text-exposition snapshot of the fleet
/// registry, self-validated before it lands on disk (a malformed
/// snapshot fails the run, like the trace exporters).
fn write_metrics_snapshot(
    cfg: &DeployConfig,
    fm: Option<&FleetMetrics>,
    metrics_path: Option<&Path>,
) -> Result<()> {
    let (Some(path), Some(m)) = (metrics_path, fm) else {
        return Ok(());
    };
    let samples = telemetry::write_prometheus(path, m, cfg.straggler_factor)?;
    println!("  metrics: {samples} samples -> {}", path.display());
    Ok(())
}

fn print_serve_report(cfg: &DeployConfig, report: &RunReport) {
    println!("{}", report.summary_line());
    println!(
        "  p50 latency {:.1}ms  p99 {:.1}ms  comm fraction {:.1}%  mean accepted {:.2}",
        report.request_latency.quantile(0.5) as f64 / 1e6,
        report.request_latency.quantile(0.99) as f64 / 1e6,
        report.comm_fraction() * 100.0,
        report.accept.mean_accepted(),
    );
    if report.ttft.count() > 0 {
        println!(
            "  ttft p50 {:.1}ms  p99 {:.1}ms  (arrival -> first committed round)",
            report.ttft.quantile(0.5) as f64 / 1e6,
            report.ttft.quantile(0.99) as f64 / 1e6,
        );
    }
    if cfg.decode.policy.is_speculative() && cfg.decode.overlap {
        println!(
            "  overlap: reuse {:.1}%  hidden {:.1}%  recovered {:.2}ms  wasted/rnd {:.2}",
            report.accept.reuse_rate() * 100.0,
            report.accept.overlap_ratio() * 100.0,
            report.accept.recovered_ns as f64 / 1e6,
            report.accept.wasted_per_round(),
        );
    }
    if cfg.decode.policy.is_speculative() {
        println!(
            "  controller {}: mean γ {:.2}  mean τ {:.3}  regret {:.3} ms/tok",
            cfg.decode.controller.name(),
            report.accept.mean_gamma(),
            report.accept.mean_tau(),
            report.accept.mean_regret_ns() / 1e6,
        );
    }
    if cfg.decode.policy.is_speculative() && cfg.fuse && cfg.max_fuse > 1 {
        println!(
            "  fused: {:.1}% of rounds shared a pass  mean group width {:.2} (cap {})",
            report.accept.fused_round_rate() * 100.0,
            report.accept.mean_fuse_width(),
            cfg.max_fuse,
        );
    }
    if report.drift.count() > 0 {
        println!(
            "  drift: {} rounds  mean {:.4}ms  max {:.4}ms{}",
            report.drift.count(),
            report.drift.mean() / 1e6,
            report.drift.max() as f64 / 1e6,
            if report.drift.max() == 0 { "  (exact)" } else { "" },
        );
    }
    if !report.node_compute_ns.is_empty() || !report.link_busy_ns.is_empty() {
        let pct = |ns: u64| {
            if report.elapsed_ns == 0 {
                0.0
            } else {
                ns as f64 / report.elapsed_ns as f64 * 100.0
            }
        };
        println!(
            "  fleet: {} nodes / {} links  (straggler factor {}x, calibrate {})",
            report.node_compute_ns.len(),
            report.link_busy_ns.len(),
            cfg.straggler_factor,
            if cfg.calibrate { "on" } else { "off" },
        );
        for (i, &c) in report.node_compute_ns.iter().enumerate() {
            println!("    node {i}: compute {:>9.1}ms  util {:>5.1}%", c as f64 / 1e6, pct(c));
        }
        for (i, &b) in report.link_busy_ns.iter().enumerate() {
            let est = report.link_hop_est_ns.get(i).copied().unwrap_or(0);
            println!(
                "    link {i}: busy    {:>9.1}ms  occ  {:>5.1}%  hop est {:.2}ms{}",
                b as f64 / 1e6,
                pct(b),
                est as f64 / 1e6,
                if report.stragglers.contains(&i) { "  STRAGGLER" } else { "" },
            );
        }
    }
}

/// `--trace` / `--json` side outputs, schema-validated right after
/// writing so a malformed export fails the run (and the CI smoke).
fn write_outputs(
    cfg: &DeployConfig,
    report: &RunReport,
    events: &[SpanEvent],
    trace_path: Option<&Path>,
    json_dir: Option<&Path>,
) -> Result<()> {
    if let Some(path) = trace_path {
        drift::validate_spans(events)?;
        export::write_perfetto(path, events)?;
        let jsonl = path.with_extension("jsonl");
        export::write_jsonl(&jsonl, events)?;
        let pairs = export::validate_perfetto(&std::fs::read_to_string(path)?)?;
        let rounds = export::validate_jsonl(&std::fs::read_to_string(&jsonl)?)?;
        let audit = drift::audit(events.iter());
        println!(
            "  trace: {} spans -> {} ({} B/E pairs) + {} ({} rounds)",
            events.len(),
            path.display(),
            pairs,
            jsonl.display(),
            rounds,
        );
        println!(
            "  trace drift: {}/{} rounds exact  max {}ns  mean {:.1}ns",
            audit.exact,
            audit.rounds,
            audit.max_ns,
            audit.mean_ns(),
        );
    }
    if let Some(dir) = json_dir {
        let v = Value::obj(&[
            ("policy", cfg.decode.policy.name().into()),
            ("nodes", cfg.n_nodes.into()),
            ("link_ms", cfg.link_ms.into()),
            ("gamma", cfg.decode.gamma.into()),
            ("controller", cfg.decode.controller.name().into()),
            ("requests", report.requests.into()),
            ("tokens", report.tokens.into()),
            ("throughput_tok_s", report.throughput().into()),
            ("ms_per_token", report.ms_per_token().into()),
            ("p50_ms", (report.request_latency.quantile(0.5) as f64 / 1e6).into()),
            ("p99_ms", (report.request_latency.quantile(0.99) as f64 / 1e6).into()),
            ("ttft_p50_ms", (report.ttft.quantile(0.5) as f64 / 1e6).into()),
            ("ttft_p99_ms", (report.ttft.quantile(0.99) as f64 / 1e6).into()),
            ("comm_fraction", report.comm_fraction().into()),
            ("acceptance_rate", report.accept.acceptance_rate().into()),
            ("mean_accepted", report.accept.mean_accepted().into()),
            ("reuse_rate", report.accept.reuse_rate().into()),
            ("fused_round_rate", report.accept.fused_round_rate().into()),
            ("mean_fuse_width", report.accept.mean_fuse_width().into()),
            ("drift_rounds", report.drift.count().into()),
            ("drift_max_ns", report.drift.max().into()),
            ("drift_mean_ns", report.drift.mean().into()),
            ("straggler_factor", cfg.straggler_factor.into()),
            (
                "node_compute_ns",
                report
                    .node_compute_ns
                    .iter()
                    .map(|&v| Value::from(v))
                    .collect::<Vec<_>>()
                    .into(),
            ),
            (
                "link_busy_ns",
                report.link_busy_ns.iter().map(|&v| Value::from(v)).collect::<Vec<_>>().into(),
            ),
            (
                "link_hop_est_ns",
                report
                    .link_hop_est_ns
                    .iter()
                    .map(|&v| Value::from(v))
                    .collect::<Vec<_>>()
                    .into(),
            ),
            (
                "stragglers",
                report.stragglers.iter().map(|&v| Value::from(v)).collect::<Vec<_>>().into(),
            ),
        ]);
        let path = write_bench_json_in(dir, "serve", &v)?;
        println!("  wrote {}", path.display());
    }
    Ok(())
}

fn compare(args: &cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let mut t = Table::new(
        format!(
            "{} | N={} t1={}ms γ={} τ={}",
            cfg.dataset, cfg.n_nodes, cfg.link_ms, cfg.decode.gamma, cfg.decode.tau
        ),
        &["system", "tok/s", "ms/tok", "avg len", "comm ms/tok", "speedup"],
    );
    let mut base: Option<RunReport> = None;
    for policy in [Policy::Autoregressive, Policy::Eagle3, Policy::Dsd] {
        let mut c = cfg.clone();
        c.decode.policy = policy;
        let report = run_once(&c)?;
        let speedup = base.as_ref().map(|b| report.speedup_over(b)).unwrap_or(1.0);
        t.row(vec![
            policy.name().to_string(),
            fnum(report.throughput(), 1),
            fnum(report.ms_per_token(), 2),
            fnum(report.accept.mean_committed(), 2),
            fnum(report.comm_ns as f64 / 1e6 / report.tokens.max(1) as f64, 2),
            fnum(speedup, 2),
        ]);
        if base.is_none() {
            base = Some(report);
        }
    }
    t.print();
    Ok(())
}

fn sweep(args: &cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let nodes = args.usize_list_or("sweep_nodes", &[2, 4, 8])?;
    let mut t = Table::new(
        format!("node sweep | {} t1={}ms", cfg.dataset, cfg.link_ms),
        &["N", "policy", "tok/s", "ms/tok", "comm ms/tok"],
    );
    for n in nodes {
        for policy in [Policy::Autoregressive, Policy::Dsd] {
            let mut c = cfg.clone();
            c.n_nodes = n;
            c.decode.policy = policy;
            let r = run_once(&c)?;
            t.row(vec![
                n.to_string(),
                policy.name().to_string(),
                fnum(r.throughput(), 1),
                fnum(r.ms_per_token(), 2),
                fnum(r.comm_ns as f64 / 1e6 / r.tokens.max(1) as f64, 2),
            ]);
        }
    }
    t.print();
    Ok(())
}

fn inspect(args: &cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let manifest = dsd::runtime::Manifest::load(&cfg.artifacts_dir)?;
    let m = &manifest.model;
    println!(
        "model: vocab={} d_model={} heads={} layers={} max_seq={} prefill={}",
        m.vocab, m.d_model, m.n_heads, m.n_layers, m.max_seq, m.prefill_window
    );
    println!("shard counts: {:?}  gammas: {:?}", manifest.shard_counts, manifest.gammas);
    println!("draft variants (agreement ladder):");
    for v in &manifest.draft_variants {
        println!(
            "  {:>8}: {} layers, sigma={:.2}, greedy-agree={:.3}, overlap={:.3}",
            v.name, v.layers, v.sigma, v.greedy_agree, v.overlap
        );
    }
    println!("{} artifacts:", manifest.artifacts.len());
    for (name, a) in &manifest.artifacts {
        println!(
            "  {:<24} kind={:<10} window={:<3} params={}",
            name,
            format!("{:?}", a.kind),
            a.window,
            a.params.len()
        );
    }
    Ok(())
}

fn init_config(args: &cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let path = args.str_or("out", "deploy.toml");
    std::fs::write(&path, cfg.to_toml())?;
    println!("wrote {path}");
    Ok(())
}
