//! Analytic models from the paper: the communication/latency equations
//! (§2.2, §2.4) and the roofline view (Fig. 1).
//!
//! These closed forms are validated against the discrete-event simulator
//! by `benches/analytic_validation.rs` (E8 in DESIGN.md §4).

pub mod roofline;

pub use roofline::{RooflinePoint, TpuLikeRoofline};

/// Parameters of the paper's latency model (§2.2).
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    /// Local compute time per decoding step (seconds) — t0.
    pub t0: f64,
    /// Point-to-point link latency (seconds) — t1.
    pub t1: f64,
    /// Number of nodes — N.
    pub n: usize,
}

impl LatencyModel {
    pub fn new(t0: f64, t1: f64, n: usize) -> LatencyModel {
        LatencyModel { t0, t1, n }
    }

    fn hops(&self) -> f64 {
        (self.n.saturating_sub(1)) as f64
    }

    /// Eq. 3: time for k tokens under standard autoregressive decoding,
    /// `T_std = k (t0 + (N-1) t1)`.
    pub fn t_std(&self, k: f64) -> f64 {
        k * (self.t0 + self.hops() * self.t1)
    }

    /// Eq. 4: time for k tokens under DSD (one sync round per window),
    /// `T_DSD = k t0 + (N-1) t1`.
    pub fn t_dsd(&self, k: f64) -> f64 {
        k * self.t0 + self.hops() * self.t1
    }

    /// Eq. 5: communication reduction ratio
    /// `R_comm = (N-1) t1 (k-1) / (k (t0 + (N-1) t1))`.
    pub fn r_comm(&self, k: f64) -> f64 {
        if k <= 0.0 {
            return 0.0;
        }
        (self.hops() * self.t1 * (k - 1.0)) / (k * (self.t0 + self.hops() * self.t1))
    }

    /// Eq. 9: expected speedup with mean acceptance ratio ρ = k/(γ+1),
    /// `S = (t0 + (N-1) t1) / (t0/ρ + (N-1) t1 / k)`.
    pub fn speedup(&self, k: f64, gamma: usize) -> f64 {
        let rho = k / (gamma as f64 + 1.0);
        if rho <= 0.0 || k <= 0.0 {
            return 0.0;
        }
        (self.t0 + self.hops() * self.t1) / (self.t0 / rho + self.hops() * self.t1 / k)
    }

    /// The paper's abstract-level approximation of saved communication per
    /// k tokens: `(N-1) t1 (k-1) / k`.
    pub fn comm_saved_per_token(&self, k: f64) -> f64 {
        if k <= 0.0 {
            return 0.0;
        }
        self.hops() * self.t1 * (k - 1.0) / k
    }

    /// Is this deployment in the paper's sweet-spot regime
    /// (3 ≤ N ≤ 8 and 3 t0 < t1 < 10 t0)?
    pub fn in_sweet_spot(&self) -> bool {
        (3..=8).contains(&self.n) && self.t1 > 3.0 * self.t0 && self.t1 < 10.0 * self.t0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_eq4_limits() {
        let m = LatencyModel::new(1.0, 4.0, 4); // t1 = 4 t0, N = 4
        // k = 1: both models identical (one token, one round)
        assert!((m.t_std(1.0) - m.t_dsd(1.0)).abs() < 1e-12);
        // large k: DSD approaches pure compute
        let k = 1000.0;
        assert!(m.t_dsd(k) < m.t_std(k) / 5.0);
    }

    #[test]
    fn eq5_matches_definition() {
        let m = LatencyModel::new(1.0, 4.0, 4);
        for k in [1.0f64, 2.0, 4.0, 8.0] {
            let direct = 1.0 - m.t_dsd(k) / m.t_std(k);
            assert!((m.r_comm(k) - direct).abs() < 1e-12, "k={k}");
        }
    }

    #[test]
    fn r_comm_zero_when_single_node() {
        let m = LatencyModel::new(1.0, 4.0, 1);
        assert_eq!(m.r_comm(8.0), 0.0);
    }

    #[test]
    fn r_comm_increases_with_k_and_saturates() {
        let m = LatencyModel::new(1.0, 5.0, 8);
        let r2 = m.r_comm(2.0);
        let r4 = m.r_comm(4.0);
        let r8 = m.r_comm(8.0);
        assert!(r2 < r4 && r4 < r8);
        let bound = m.hops() * m.t1 / (m.t0 + m.hops() * m.t1);
        assert!(r8 < bound);
        assert!(m.r_comm(1e9) > bound - 1e-6);
    }

    #[test]
    fn eq9_speedup_exceeds_one_in_sweet_spot() {
        let m = LatencyModel::new(1.0, 5.0, 4);
        assert!(m.in_sweet_spot());
        // decent acceptance: k = 4 of gamma = 8
        let s = m.speedup(4.0, 8);
        assert!(s > 1.5, "{s}");
    }

    #[test]
    fn speedup_formula_vs_times() {
        // S should equal T_std(per-token) / T_DSD(per-token) with the
        // round-structure the formula encodes: a round commits k tokens
        // at cost (gamma+1) t0 ... the paper folds drafting into rho.
        let m = LatencyModel::new(1.0, 4.0, 4);
        let k = 4.0;
        let gamma = 8;
        let rho = k / (gamma as f64 + 1.0);
        let per_token_dsd = m.t0 / rho + m.hops() * m.t1 / k;
        let s = m.speedup(k, gamma);
        assert!(((m.t0 + m.hops() * m.t1) / per_token_dsd - s).abs() < 1e-12);
    }

    #[test]
    fn sweet_spot_bounds() {
        assert!(!LatencyModel::new(1.0, 1.0, 4).in_sweet_spot()); // t1 too small
        assert!(!LatencyModel::new(1.0, 20.0, 4).in_sweet_spot()); // too big
        assert!(!LatencyModel::new(1.0, 5.0, 2).in_sweet_spot()); // N too small
        assert!(!LatencyModel::new(1.0, 5.0, 16).in_sweet_spot()); // N too big
    }
}
