//! Roofline model (paper Fig. 1): attainable FLOP/s versus arithmetic
//! intensity for decode, speculative-verify, and prefill windows.
//!
//! The paper's point: token-by-token decode is memory-bound; verifying a
//! compact draft window multiplies FLOPs per weight byte moved by W,
//! pushing effective intensity toward the compute roof. We model a
//! TPU-like accelerator (configurable peak FLOP/s and HBM bandwidth) and
//! compute intensity analytically from the transformer dimensions — the
//! same numbers DESIGN.md §6 uses for the VMEM/MXU estimates.

use crate::runtime::ModelDims;

/// A point on the roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub label: String,
    /// FLOPs per byte of weight+KV traffic.
    pub intensity: f64,
    /// Attainable fraction of peak compute, min(1, intensity/knee).
    pub attainable_flops: f64,
    pub flops: f64,
    pub bytes: f64,
}

/// Accelerator model: peak compute and memory bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct TpuLikeRoofline {
    /// Peak FLOP/s (e.g. 1.97e14 bf16 for a TPU v4 MXU).
    pub peak_flops: f64,
    /// Memory bandwidth bytes/s (e.g. 1.2e12 HBM).
    pub bandwidth: f64,
}

impl Default for TpuLikeRoofline {
    fn default() -> Self {
        // TPUv4-ish numbers; the *ratio* (knee) is what matters.
        TpuLikeRoofline { peak_flops: 1.97e14, bandwidth: 1.2e12 }
    }
}

impl TpuLikeRoofline {
    /// Intensity at which compute becomes the bound.
    pub fn knee(&self) -> f64 {
        self.peak_flops / self.bandwidth
    }

    /// Attainable FLOP/s at a given intensity.
    pub fn attainable(&self, intensity: f64) -> f64 {
        (intensity * self.bandwidth).min(self.peak_flops)
    }

    /// Roofline point for processing a window of `w` positions through the
    /// model with `context` tokens of KV history, weights in `wbytes`
    /// bytes per element.
    pub fn window_point(
        &self,
        dims: &ModelDims,
        w: usize,
        context: usize,
        label: &str,
    ) -> RooflinePoint {
        let flops = transformer_window_flops(dims, w, context);
        let bytes = transformer_window_bytes(dims, w, context);
        let intensity = flops / bytes;
        RooflinePoint {
            label: label.to_string(),
            intensity,
            attainable_flops: self.attainable(intensity),
            flops,
            bytes,
        }
    }

    /// The Fig. 1 series: decode (W=1), verify windows, prefill.
    pub fn figure1(
        &self,
        dims: &ModelDims,
        gammas: &[usize],
        context: usize,
    ) -> Vec<RooflinePoint> {
        let mut pts = vec![self.window_point(dims, 1, context, "decode W=1")];
        for &g in gammas {
            pts.push(self.window_point(
                dims,
                g + 1,
                context,
                &format!("verify W={}", g + 1),
            ));
        }
        pts.push(self.window_point(dims, dims.prefill_window, 0, "prefill"));
        pts
    }
}

/// FLOPs to run `w` new positions with `context` cached tokens.
pub fn transformer_window_flops(dims: &ModelDims, w: usize, context: usize) -> f64 {
    let d = dims.d_model as f64;
    let ff = dims.d_ff as f64;
    let v = dims.vocab as f64;
    let l = dims.n_layers as f64;
    let w = w as f64;
    let s = context as f64 + w;
    // per layer: qkv+out projections 4 d^2, mlp 2 d ff, attention 2 s d
    let per_layer = w * (4.0 * 2.0 * d * d + 2.0 * 2.0 * d * ff + 2.0 * 2.0 * s * d);
    l * per_layer + w * 2.0 * d * v // unembed
}

/// Effective bytes one host distribution kernel touches: `rows_read`
/// vocab-length f32 rows read plus `rows_written` written — the traffic
/// the *task* requires, not the traffic an implementation happens to
/// generate, so legacy and vectorized forms of the same kernel are
/// scored against the same byte count (`benches/hotpath.rs` kernel
/// suite).
pub fn host_row_bytes(vocab: usize, rows_read: usize, rows_written: usize) -> f64 {
    (vocab * 4 * (rows_read + rows_written)) as f64
}

/// Effective bandwidth in GB/s from bytes touched and elapsed
/// nanoseconds (1 GB = 1e9 bytes, so bytes/ns IS GB/s exactly).
pub fn effective_gbps(bytes: f64, ns: f64) -> f64 {
    if ns <= 0.0 {
        0.0
    } else {
        bytes / ns
    }
}

/// Bytes moved: weights once per pass + KV history + activations.
pub fn transformer_window_bytes(dims: &ModelDims, w: usize, context: usize) -> f64 {
    let d = dims.d_model as f64;
    let ff = dims.d_ff as f64;
    let v = dims.vocab as f64;
    let l = dims.n_layers as f64;
    let s = context as f64 + w as f64;
    let elem = 4.0; // f32 artifacts; bf16 on real TPUs halves this uniformly
    let weights = l * (4.0 * d * d + 2.0 * d * ff) + d * v + v * d;
    let kv = l * 2.0 * s * d;
    let act = w as f64 * d * l;
    elem * (weights + kv + act)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 512,
            d_model: 128,
            n_heads: 4,
            head_dim: 32,
            d_ff: 512,
            n_layers: 8,
            max_seq: 192,
            prefill_window: 64,
            logit_scale: 4.0,
        }
    }

    #[test]
    fn verify_window_raises_intensity() {
        let r = TpuLikeRoofline::default();
        let d = dims();
        let decode = r.window_point(&d, 1, 64, "decode");
        let verify = r.window_point(&d, 9, 64, "verify");
        let prefill = r.window_point(&d, 64, 0, "prefill");
        assert!(verify.intensity > 3.0 * decode.intensity);
        assert!(prefill.intensity > verify.intensity);
        assert!(verify.attainable_flops > decode.attainable_flops);
    }

    #[test]
    fn attainable_capped_at_peak() {
        let r = TpuLikeRoofline::default();
        assert_eq!(r.attainable(1e9), r.peak_flops);
        assert!(r.attainable(1.0) < r.peak_flops);
        assert!(r.knee() > 100.0 && r.knee() < 300.0);
    }

    #[test]
    fn figure1_series_is_monotone_in_window() {
        let r = TpuLikeRoofline::default();
        let pts = r.figure1(&dims(), &[4, 8], 64);
        assert_eq!(pts.len(), 4);
        for w in pts.windows(2) {
            assert!(w[1].intensity > w[0].intensity, "{w:?}");
        }
    }

    #[test]
    fn host_row_bytes_and_gbps_are_exact() {
        // one 32k-vocab row read + one written = 256 KiB
        let b = host_row_bytes(32768, 1, 1);
        assert_eq!(b, 262144.0);
        // 256 KiB in 262144 ns = exactly 1 GB/s (bytes/ns)
        assert_eq!(effective_gbps(b, 262144.0), 1.0);
        assert_eq!(effective_gbps(b, 0.0), 0.0);
        assert_eq!(host_row_bytes(100, 2, 1), 1200.0);
    }

    #[test]
    fn flops_scale_linearly_with_window() {
        let d = dims();
        let f1 = transformer_window_flops(&d, 1, 64);
        let f9 = transformer_window_flops(&d, 9, 64);
        assert!(f9 > 8.0 * f1 && f9 < 10.0 * f1);
    }
}
