//! Host-side tensors: the data that crosses node boundaries.
//!
//! PJRT handles (`Literal`, `PjRtBuffer`) hold raw pointers and are not
//! `Send`, so everything that travels between node threads — activations,
//! logits, tokens, KV snapshots — is a plain `HostTensor`. Conversion to
//! and from literals happens inside each node's `Engine`.

use anyhow::{bail, Result};

/// Row-major host tensor, f32 or i32 (the only dtypes in the artifact set).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32 { data, shape }
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32 { data, shape }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { data: vec![x], shape: vec![] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::I32 { data: vec![x], shape: vec![] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor::F32 {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes — what the simulated network charges for.
    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "float32",
            HostTensor::I32 { .. } => "int32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn scalar_i32_value(&self) -> Result<i32> {
        let d = self.as_i32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elements", d.len());
        }
        Ok(d[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_sizes() {
        let t = HostTensor::zeros_f32(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn dtype_enforcement() {
        let t = HostTensor::i32(vec![1, 2], vec![2]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
        assert_eq!(HostTensor::scalar_i32(5).scalar_i32_value().unwrap(), 5);
    }
}
