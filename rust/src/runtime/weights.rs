//! Memory-mapped-style access to `weights.bin`.
//!
//! The blob is read once into an `Arc<[u8]>` and shared by every engine in
//! the process (weight *buffers* are per-PJRT-client, but the host copy is
//! shared). Tensors are sliced out lazily by manifest offset.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, TensorRec};

/// Shared host copy of weights.bin.
#[derive(Clone)]
pub struct WeightStore {
    blob: Arc<Vec<u8>>,
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let path = manifest.dir.join(&manifest.weights_file);
        Self::load_path(&path)
    }

    pub fn load_path(path: impl AsRef<Path>) -> Result<WeightStore> {
        let blob = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Ok(WeightStore { blob: Arc::new(blob) })
    }

    pub fn size(&self) -> usize {
        self.blob.len()
    }

    /// Raw little-endian f32 bytes for one tensor.
    pub fn tensor_bytes(&self, rec: &TensorRec) -> Result<&[u8]> {
        let end = rec.offset + rec.size_bytes();
        if end > self.blob.len() {
            bail!(
                "tensor out of bounds: offset {} + {} > blob {}",
                rec.offset,
                rec.size_bytes(),
                self.blob.len()
            );
        }
        Ok(&self.blob[rec.offset..end])
    }

    /// Decode one tensor to f32 (host copy).
    pub fn tensor_f32(&self, rec: &TensorRec) -> Result<Vec<f32>> {
        let bytes = self.tensor_bytes(rec)?;
        let mut out = Vec::with_capacity(rec.num_elements());
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(out)
    }
}

/// Map a stage-local parameter name (`layer0.wq`) to the global weight-set
/// name (`layer{base+0}.wq`). Non-layer names pass through.
pub fn resolve_param_name(local: &str, layer_base: usize) -> String {
    if let Some(rest) = local.strip_prefix("layer") {
        if let Some((idx, field)) = rest.split_once('.') {
            if let Ok(i) = idx.parse::<usize>() {
                return format!("layer{}.{}", i + layer_base, field);
            }
        }
    }
    local.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_name_resolution() {
        assert_eq!(resolve_param_name("layer0.wq", 4), "layer4.wq");
        assert_eq!(resolve_param_name("layer3.b2", 0), "layer3.b2");
        assert_eq!(resolve_param_name("embed", 4), "embed");
        assert_eq!(resolve_param_name("lnf_scale", 2), "lnf_scale");
    }

    #[test]
    fn tensor_bounds_checked() {
        let store = WeightStore { blob: Arc::new(vec![0u8; 16]) };
        let ok = TensorRec { offset: 0, shape: vec![4] };
        assert_eq!(store.tensor_f32(&ok).unwrap().len(), 4);
        let bad = TensorRec { offset: 8, shape: vec![4] };
        assert!(store.tensor_f32(&bad).is_err());
    }

    #[test]
    fn tensor_decodes_le_f32() {
        let mut blob = Vec::new();
        blob.extend_from_slice(&1.5f32.to_le_bytes());
        blob.extend_from_slice(&(-2.0f32).to_le_bytes());
        let store = WeightStore { blob: Arc::new(blob) };
        let rec = TensorRec { offset: 0, shape: vec![2] };
        assert_eq!(store.tensor_f32(&rec).unwrap(), vec![1.5, -2.0]);
    }
}
