//! Parse `artifacts/manifest.json` — the schema contract with `aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// Architecture constants of the exported model. All-scalar, so `Copy`
/// — the decode engine caches one per construction instead of re-reading
/// the manifest every round.
#[derive(Debug, Clone, Copy)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub prefill_window: usize,
    pub logit_scale: f64,
}

/// One tensor's location inside weights.bin.
#[derive(Debug, Clone)]
pub struct TensorRec {
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl TensorRec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.num_elements() * 4
    }
}

/// Calibrated draft weight-set variant (the agreement ladder).
#[derive(Debug, Clone)]
pub struct DraftVariant {
    pub name: String,
    pub layers: usize,
    pub sigma: f64,
    pub greedy_agree: f64,
    pub overlap: f64,
}

impl DraftVariant {
    pub fn weight_set(&self) -> String {
        format!("draft_{}", self.name)
    }
}

/// Runtime input/output slot of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactKind {
    Stage,
    DraftStep,
    Verify,
}

/// Metadata for one HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    /// 'first' | 'mid' | 'last' | 'full' for stages.
    pub role: Option<String>,
    /// Layers per stage (stage/draft artifacts).
    pub layers: Option<usize>,
    pub window: usize,
    pub gamma: Option<usize>,
    /// Weight parameter names, in HLO positional order, stage-local.
    pub params: Vec<String>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub shard_counts: Vec<usize>,
    pub gammas: Vec<usize>,
    pub seed: u64,
    pub weights_file: String,
    pub weight_sets: BTreeMap<String, BTreeMap<String, TensorRec>>,
    pub draft_variants: Vec<DraftVariant>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn io_specs(v: &Value) -> Result<Vec<IoSpec>> {
    v.as_array()
        .ok_or_else(|| anyhow!("io spec list is not an array"))?
        .iter()
        .map(|s| {
            Ok(IoSpec {
                name: s.str_field("name")?.to_string(),
                shape: s.usize_array_field("shape")?,
                dtype: s.str_field("dtype")?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let m = v.get("model")?;
        let model = ModelDims {
            vocab: m.usize_field("vocab")?,
            d_model: m.usize_field("d_model")?,
            n_heads: m.usize_field("n_heads")?,
            head_dim: m.usize_field("head_dim")?,
            d_ff: m.usize_field("d_ff")?,
            n_layers: m.usize_field("n_layers")?,
            max_seq: m.usize_field("max_seq")?,
            prefill_window: m.usize_field("prefill_window")?,
            logit_scale: m.f64_field("logit_scale")?,
        };

        let mut weight_sets = BTreeMap::new();
        for (set, tensors) in v
            .get("weight_sets")?
            .as_object()
            .ok_or_else(|| anyhow!("weight_sets not an object"))?
        {
            let mut map = BTreeMap::new();
            for (name, rec) in tensors
                .as_object()
                .ok_or_else(|| anyhow!("weight set {set} not an object"))?
            {
                map.insert(
                    name.clone(),
                    TensorRec {
                        offset: rec.usize_field("offset")?,
                        shape: rec.usize_array_field("shape")?,
                    },
                );
            }
            weight_sets.insert(set.clone(), map);
        }

        let draft_variants = v
            .get("draft_variants")?
            .as_array()
            .ok_or_else(|| anyhow!("draft_variants not an array"))?
            .iter()
            .map(|d| {
                Ok(DraftVariant {
                    name: d.str_field("name")?.to_string(),
                    layers: d.usize_field("layers")?,
                    sigma: d.f64_field("sigma")?,
                    greedy_agree: d.f64_field("greedy_agree")?,
                    overlap: d.f64_field("overlap")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in v
            .get("artifacts")?
            .as_object()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let kind = match a.str_field("kind")? {
                "stage" => ArtifactKind::Stage,
                "draft_step" => ArtifactKind::DraftStep,
                "verify" => ArtifactKind::Verify,
                other => bail!("unknown artifact kind '{other}'"),
            };
            let params = a
                .get("params")?
                .as_array()
                .ok_or_else(|| anyhow!("params not an array"))?
                .iter()
                .map(|p| {
                    p.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("param not a string"))
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: a.str_field("file")?.to_string(),
                    kind,
                    role: a.get_opt("role").and_then(|r| r.as_str()).map(str::to_string),
                    layers: a.get_opt("layers").and_then(|l| l.as_usize()),
                    window: a.usize_field("window")?,
                    gamma: a.get_opt("gamma").and_then(|g| g.as_usize()),
                    params,
                    inputs: io_specs(a.get("inputs")?)?,
                    outputs: io_specs(a.get("outputs")?)?,
                },
            );
        }

        Ok(Manifest {
            dir,
            model,
            shard_counts: v.usize_array_field("shard_counts")?,
            gammas: v.usize_array_field("gammas")?,
            seed: v.usize_field("seed")? as u64,
            weights_file: v.str_field("weights_file")?.to_string(),
            weight_sets,
            draft_variants,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Whether an artifact exists (capability probe — e.g. tree-attention
    /// stage variants, which older artifact exports lack).
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn weight_set(&self, name: &str) -> Result<&BTreeMap<String, TensorRec>> {
        self.weight_sets
            .get(name)
            .ok_or_else(|| anyhow!("weight set '{name}' not in manifest"))
    }

    /// The draft variant whose measured overlap best matches `target`.
    pub fn variant_by_overlap(&self, target: f64) -> Result<&DraftVariant> {
        self.draft_variants
            .iter()
            .min_by(|a, b| {
                (a.overlap - target)
                    .abs()
                    .partial_cmp(&(b.overlap - target).abs())
                    .unwrap()
            })
            .ok_or_else(|| anyhow!("no draft variants in manifest"))
    }

    pub fn variant(&self, name: &str) -> Result<&DraftVariant> {
        self.draft_variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| anyhow!("draft variant '{name}' not in manifest"))
    }

    /// Name of the stage artifact for (role, layers-per-stage, window).
    pub fn stage_artifact_name(role: &str, lps: usize, window: usize) -> String {
        format!("target_{role}{lps}_w{window}")
    }

    /// Name of the tree-attention stage artifact (flattened token-tree
    /// verify windows: extra position-id and ancestor-mask inputs).
    pub fn stage_tree_artifact_name(role: &str, lps: usize, window: usize) -> String {
        format!("target_{role}{lps}_tree{window}")
    }

    /// Layers-per-stage for a shard count.
    pub fn layers_per_stage(&self, n_shards: usize) -> Result<usize> {
        if n_shards == 0 || self.model.n_layers % n_shards != 0 {
            bail!(
                "{} layers not divisible into {n_shards} stages",
                self.model.n_layers
            );
        }
        Ok(self.model.n_layers / n_shards)
    }

    /// Stage roles for a shard count (mirrors config.stage_roles in python).
    pub fn stage_roles(n_shards: usize) -> Vec<&'static str> {
        if n_shards == 1 {
            return vec!["full"];
        }
        let mut roles = vec!["first"];
        for _ in 0..n_shards.saturating_sub(2) {
            roles.push("mid");
        }
        roles.push("last");
        roles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_roles_shapes() {
        assert_eq!(Manifest::stage_roles(1), vec!["full"]);
        assert_eq!(Manifest::stage_roles(2), vec!["first", "last"]);
        assert_eq!(
            Manifest::stage_roles(4),
            vec!["first", "mid", "mid", "last"]
        );
    }

    #[test]
    fn stage_artifact_names() {
        assert_eq!(
            Manifest::stage_artifact_name("first", 4, 5),
            "target_first4_w5"
        );
    }
}
