//! PJRT runtime layer: artifact manifest, weight store, execution engine.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it (coordinator, spec decoding, cluster) works with [`HostTensor`]s and
//! artifact names, so the rest of the stack is testable without PJRT.

pub mod engine;
pub mod manifest;
pub mod tensor;
pub mod weights;

pub use engine::{Engine, EngineStats};
pub use manifest::{
    ArtifactKind, ArtifactMeta, DraftVariant, IoSpec, Manifest, ModelDims, TensorRec,
};
pub use tensor::HostTensor;
pub use weights::{resolve_param_name, WeightStore};
