//! The PJRT execution engine: load HLO-text artifacts, compile once, bind
//! weight sets as device-resident buffers, execute with host tensors.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so an `Engine` is
//! thread-confined. Each cluster node thread builds its own engine — which
//! mirrors a real decentralized deployment, where every node runs its own
//! runtime. The host weight blob is shared (`WeightStore` is `Arc`ed);
//! device weight buffers are uploaded once per engine and cached.

// On the sim-time allowlist (LINTS.md): engine compile/upload/execute
// timing is measured wall time by design.
#![allow(clippy::disallowed_methods)]

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{ArtifactMeta, Manifest};
use super::tensor::HostTensor;
use super::weights::{resolve_param_name, WeightStore};

/// Cumulative engine counters (observability for the metrics layer).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
    pub exec_nanos: u64,
    pub upload_nanos: u64,
    pub download_nanos: u64,
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
}

/// Thread-confined PJRT engine over one artifact directory.
pub struct Engine {
    client: PjRtClient,
    manifest: Rc<Manifest>,
    weights: WeightStore,
    executables: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// (artifact, weight_set, layer_base) -> uploaded weight buffers.
    weight_buffers: RefCell<HashMap<(String, String, usize), Rc<Vec<PjRtBuffer>>>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create an engine over an already-loaded manifest + weight store.
    pub fn new(manifest: Rc<Manifest>, weights: WeightStore) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            weights,
            executables: RefCell::new(HashMap::new()),
            weight_buffers: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    /// Convenience: load manifest + weights from an artifact directory.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Rc::new(Manifest::load(dir)?);
        let weights = WeightStore::load(&manifest)?;
        Engine::new(manifest, weights)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }

    /// Compile (and cache) an artifact's executable.
    pub fn ensure_compiled(&self, artifact: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.borrow().get(artifact) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.artifact(artifact)?;
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{artifact}'"))?;
        self.stats.borrow_mut().compiles += 1;
        let exe = Rc::new(exe);
        self.executables
            .borrow_mut()
            .insert(artifact.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload (and cache) the weight buffers for (artifact, weight_set,
    /// layer_base). layer_base maps stage-local layer indices to global
    /// ones (stage s of a pipeline with L layers/stage has base s*L).
    pub fn ensure_weights(
        &self,
        artifact: &str,
        weight_set: &str,
        layer_base: usize,
    ) -> Result<Rc<Vec<PjRtBuffer>>> {
        let key = (artifact.to_string(), weight_set.to_string(), layer_base);
        if let Some(bufs) = self.weight_buffers.borrow().get(&key) {
            return Ok(bufs.clone());
        }
        let meta = self.manifest.artifact(artifact)?;
        let set = self.manifest.weight_set(weight_set)?;
        let mut bufs = Vec::with_capacity(meta.params.len());
        let t0 = Instant::now();
        let mut bytes = 0u64;
        for local in &meta.params {
            let global = resolve_param_name(local, layer_base);
            let rec = set.get(&global).ok_or_else(|| {
                anyhow!("weight '{global}' (local '{local}') missing from set '{weight_set}'")
            })?;
            let data = self.weights.tensor_f32(rec)?;
            bytes += (data.len() * 4) as u64;
            let buf = self
                .client
                .buffer_from_host_buffer(&data, &rec.shape, None)
                .with_context(|| format!("uploading weight '{global}'"))?;
            bufs.push(buf);
        }
        {
            let mut s = self.stats.borrow_mut();
            s.upload_nanos += t0.elapsed().as_nanos() as u64;
            s.bytes_uploaded += bytes;
        }
        let bufs = Rc::new(bufs);
        self.weight_buffers.borrow_mut().insert(key, bufs.clone());
        Ok(bufs)
    }

    /// Upload one host tensor as a device buffer.
    ///
    /// Uses the typed `buffer_from_host_buffer`, which (a) maps to PJRT's
    /// `kImmutableOnlyDuringCall` semantics — the copy completes before the
    /// call returns, so the host memory may be freed immediately — and
    /// (b) passes the correct `PrimitiveType`. Two upstream traps avoided:
    /// `buffer_from_host_literal` is asynchronous (the literal must outlive
    /// the transfer → use-after-free), and `buffer_from_host_raw_bytes`
    /// passes `ElementType as i32` where the C shim expects a
    /// `PrimitiveType`, mislabeling F32 data as F16.
    fn upload(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        let dims: Vec<usize> = t.shape().to_vec();
        Ok(match t {
            HostTensor::F32 { data, .. } => {
                self.client.buffer_from_host_buffer(data, &dims, None)?
            }
            HostTensor::I32 { data, .. } => {
                self.client.buffer_from_host_buffer(data, &dims, None)?
            }
        })
    }

    fn host_of(&self, lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(HostTensor::f32(lit.to_vec::<f32>()?, dims)),
            ElementType::S32 => Ok(HostTensor::i32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported artifact output dtype {other:?}"),
        }
    }

    /// Execute an artifact: weights (cached device buffers) + runtime
    /// inputs (uploaded per call). Returns host tensors in the artifact's
    /// declared output order.
    pub fn run(
        &self,
        artifact: &str,
        weight_set: &str,
        layer_base: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{artifact}' expects {} runtime inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        self.validate_inputs(&meta, inputs)?;
        let exe = self.ensure_compiled(artifact)?;
        let wbufs = if meta.params.is_empty() {
            Rc::new(Vec::new())
        } else {
            self.ensure_weights(artifact, weight_set, layer_base)?
        };

        // Upload runtime inputs.
        let t_up = Instant::now();
        let mut in_bufs: Vec<PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut up_bytes = 0u64;
        for t in inputs {
            up_bytes += t.size_bytes() as u64;
            in_bufs.push(self.upload(t)?);
        }
        let upload_nanos = t_up.elapsed().as_nanos() as u64;

        // Assemble the positional argument list: weights then inputs.
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(wbufs.len() + in_bufs.len());
        args.extend(wbufs.iter());
        args.extend(in_bufs.iter());

        let t_exec = Instant::now();
        let result = exe
            .execute_b(&args)
            .with_context(|| format!("executing '{artifact}'"))?;
        let exec_nanos = t_exec.elapsed().as_nanos() as u64;

        // One replica, one tuple-valued output buffer (return_tuple=True).
        let t_down = Instant::now();
        let out_buf = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("artifact '{artifact}' produced no outputs"))?;
        let lit = out_buf.to_literal_sync()?;
        let leaves = lit.to_tuple()?;
        let mut outs = Vec::with_capacity(leaves.len());
        let mut down_bytes = 0u64;
        for leaf in &leaves {
            let t = self.host_of(leaf)?;
            down_bytes += t.size_bytes() as u64;
            outs.push(t);
        }
        let download_nanos = t_down.elapsed().as_nanos() as u64;

        if outs.len() != meta.outputs.len() {
            bail!(
                "artifact '{artifact}' returned {} outputs, manifest says {}",
                outs.len(),
                meta.outputs.len()
            );
        }
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.exec_nanos += exec_nanos;
            s.upload_nanos += upload_nanos;
            s.download_nanos += download_nanos;
            s.bytes_uploaded += up_bytes;
            s.bytes_downloaded += down_bytes;
        }
        Ok(outs)
    }

    fn validate_inputs(&self, meta: &ArtifactMeta, inputs: &[HostTensor]) -> Result<()> {
        for (spec, t) in meta.inputs.iter().zip(inputs) {
            if spec.shape != t.shape() {
                bail!(
                    "artifact '{}' input '{}': expected shape {:?}, got {:?}",
                    meta.name,
                    spec.name,
                    spec.shape,
                    t.shape()
                );
            }
            if spec.dtype != t.dtype_name() {
                bail!(
                    "artifact '{}' input '{}': expected {}, got {}",
                    meta.name,
                    spec.name,
                    spec.dtype,
                    t.dtype_name()
                );
            }
        }
        Ok(())
    }

    /// Pre-compile + pre-upload everything a node will need, so the first
    /// request doesn't pay compile latency (production warmup path).
    pub fn warmup(&self, artifacts: &[(&str, &str, usize)]) -> Result<()> {
        for (artifact, wset, base) in artifacts {
            self.ensure_compiled(artifact)?;
            let meta = self.manifest.artifact(artifact)?;
            if !meta.params.is_empty() {
                self.ensure_weights(artifact, wset, *base)?;
            }
        }
        Ok(())
    }
}
