//! Micro-benchmark helper (offline environment: no criterion). Used by
//! `benches/hotpath.rs` and the perf pass. Also home of the
//! machine-readable bench output: ablation benches write a
//! `BENCH_<name>.json` (config + headline numbers) via
//! [`write_bench_json`] so the perf trajectory is tracked across PRs
//! (CI uploads the files as workflow artifacts).

// On the sim-time allowlist (LINTS.md): benchmarking measures wall time.
#![allow(clippy::disallowed_methods)]

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Value;

/// Write `BENCH_<name>.json` in the current directory (the crate root
/// under `cargo bench`) and return the path. The value should be an
/// object carrying the bench's config and headline metrics.
pub fn write_bench_json(name: &str, value: &Value) -> std::io::Result<PathBuf> {
    write_bench_json_in(Path::new("."), name, value)
}

/// [`write_bench_json`] into an explicit directory.
pub fn write_bench_json_in(dir: &Path, name: &str, value: &Value) -> std::io::Result<PathBuf> {
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{value}\n"))?;
    Ok(path)
}

/// Result of one measured loop.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Mean allocation events per iteration, measured across the whole
    /// timed loop when the `alloc-count` feature is active; `None` when
    /// counting is compiled out (printed as `n/a`, never as a fake 0).
    pub allocs_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn line(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns >= 1e6 {
                format!("{:.2} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.2} µs", ns / 1e3)
            } else {
                format!("{:.0} ns", ns)
            }
        }
        let allocs = match self.allocs_per_iter {
            Some(a) => format!("{a:.1}"),
            None => "n/a".to_string(),
        };
        format!(
            "{:<36} iters={:<6} mean={:<10} min={:<10} p50={:<10} p95={:<10} allocs/iter={}",
            self.name,
            self.iters,
            fmt(self.mean_ns),
            fmt(self.min_ns),
            fmt(self.p50_ns),
            fmt(self.p95_ns),
            allocs,
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs. With
/// the `alloc-count` feature active, also reports the mean allocation
/// events per iteration over the timed loop (timestamping itself does
/// not allocate, so the count is the workload's own).
pub fn bench<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    let allocs_before = crate::util::alloc_counter::current().allocs;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let allocs_after = crate::util::alloc_counter::current().allocs;
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let allocs_per_iter = if crate::util::alloc_counter::enabled() {
        Some((allocs_after - allocs_before) as f64 / iters as f64)
    } else {
        None
    };
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: samples[0],
        p50_ns: samples[samples.len() / 2],
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        allocs_per_iter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_writes_parseable_file() {
        let dir = std::env::temp_dir().join("dsd_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let v = Value::obj(&[("speedup", 1.5f64.into()), ("rounds", 10usize.into())]);
        let path = write_bench_json_in(&dir, "testbench", &v).unwrap();
        assert!(path.ends_with("BENCH_testbench.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let back = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(back.f64_field("speedup").unwrap(), 1.5);
        assert_eq!(back.usize_field("rounds").unwrap(), 10);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
        assert!(r.line().contains("spin"));
    }
}
