//! Micro-benchmark helper (offline environment: no criterion). Used by
//! `benches/hotpath.rs` and the perf pass.

use std::time::Instant;

/// Result of one measured loop.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns >= 1e6 {
                format!("{:.2} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.2} µs", ns / 1e3)
            } else {
                format!("{:.0} ns", ns)
            }
        }
        format!(
            "{:<36} iters={:<6} mean={:<10} min={:<10} p50={:<10} p95={}",
            self.name,
            self.iters,
            fmt(self.mean_ns),
            fmt(self.min_ns),
            fmt(self.p50_ns),
            fmt(self.p95_ns),
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: samples[0],
        p50_ns: samples[samples.len() / 2],
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
        assert!(r.line().contains("spin"));
    }
}
