//! Deterministic PRNG for the whole stack (no `rand` in the offline cache).
//!
//! SplitMix64 for seeding + xoshiro256++ for the stream — the standard
//! pairing. Every component that needs randomness (workload generation,
//! draft sampling uniforms, latency jitter) takes an explicit `Rng` so
//! runs are reproducible from a single seed, which the experiment
//! harnesses rely on for paper-table regeneration.

/// xoshiro256++ seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// Mix two seeds into one derived seed (order-sensitive).
pub fn mix(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.wrapping_mul(0xD1B54A32D192ED03);
    splitmix64(&mut s)
}

/// Counter-based uniform in [0, 1): a pure function of
/// `(seed, stream, a, b)` with the same 24-bit mantissa convention as
/// [`Rng::f32`].
///
/// Components whose draws must be *timing-independent* key their
/// uniforms on what the draw decides (sequence, position, window slot)
/// instead of consuming a shared mutable stream. The decode engine
/// relies on this for the speculate-ahead scheduler: a draft-sampling
/// uniform for position `p` has the same value whether the step runs
/// ahead of time (inside the previous round's in-flight verify window)
/// or on the sequential path, so overlap mode commits byte-identical
/// token streams.
pub fn uniform_at(seed: u64, stream: u64, a: u64, b: u64) -> f32 {
    let mut s = seed
        ^ stream.wrapping_mul(0x9E3779B97F4A7C15)
        ^ a.wrapping_mul(0xBF58476D1CE4E5B9)
        ^ b.wrapping_mul(0x94D049BB133111EB);
    let _ = splitmix64(&mut s);
    let z = splitmix64(&mut s);
    (z >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per-request, per-node).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full float precision
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift with rejection for exactness.
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive mass");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "{p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0, 3.0];
        let n = 40_000;
        let ones = (0..n).filter(|_| r.categorical(&w) == 1).count();
        let p = ones as f64 / n as f64;
        assert!((p - 0.75).abs() < 0.01, "{p}");
    }

    #[test]
    fn uniform_at_is_pure_and_in_range() {
        for stream in 0..4u64 {
            for a in 0..64u64 {
                let x = uniform_at(7, stream, a, 3);
                assert!((0.0..1.0).contains(&x), "{x}");
                assert_eq!(x, uniform_at(7, stream, a, 3), "must be a pure function");
            }
        }
        // distinct keys give distinct draws (no systematic collisions)
        let mut vals: Vec<u32> = Vec::new();
        for stream in 0..3u64 {
            for a in 0..50u64 {
                for b in 0..4u64 {
                    vals.push(uniform_at(9, stream, a, b).to_bits());
                }
            }
        }
        let n = vals.len();
        vals.sort_unstable();
        vals.dedup();
        assert!(vals.len() > n - 3, "too many collisions: {} of {n} unique", vals.len());
    }

    #[test]
    fn uniform_at_is_unbiased_enough() {
        let n = 50_000u64;
        let mean: f64 = (0..n).map(|i| uniform_at(11, 1, i, 0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn mix_derives_distinct_seeds() {
        assert_ne!(mix(1, 2), mix(2, 1));
        assert_ne!(mix(1, 2), mix(1, 3));
        let mut a = Rng::new(mix(5, 0));
        let mut b = Rng::new(mix(5, 1));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
