//! Tiny CLI argument parser (offline environment: no clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! args, and subcommands. Used by `main.rs`, the examples, and the bench
//! harnesses.
//!
//! Each entry point passes its own `valued` allowlist (option keys that
//! consume a value). Keys shared across drivers — `nodes`, `link_ms`,
//! `gamma`, `draft_shape` (`chain` | `tree:<branching>x<depth>`),
//! `overlap` (`on` | `off`), … — should be spelled identically
//! everywhere so configs and muscle memory transfer between `dsd`, the
//! examples, and the benches.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Option keys that take a value (everything else starting with `--` is a
/// boolean flag).
pub fn parse_with(valued: &[&str], argv: impl IntoIterator<Item = String>) -> Result<Args> {
    let valued: Vec<&str> = valued.to_vec();
    let mut out = Args::default();
    let mut it = argv.into_iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(rest) = arg.strip_prefix("--") {
            if let Some((k, v)) = rest.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if valued.contains(&rest) {
                let v = it
                    .next()
                    .ok_or_else(|| anyhow!("option --{rest} needs a value"))?;
                out.options.insert(rest.to_string(), v);
            } else {
                out.flags.push(rest.to_string());
            }
        } else {
            out.positional.push(arg);
        }
    }
    Ok(out)
}

/// Parse `std::env::args()` (skipping argv[0]).
pub fn parse_env(valued: &[&str]) -> Result<Args> {
    parse_with(valued, std::env::args().skip(1))
}

/// Parse an `on|off` switch value (also accepts true/false, 1/0,
/// yes/no) — the spelling shared by `--overlap` and config files.
pub fn parse_on_off(v: &str) -> Result<bool> {
    match v.trim().to_ascii_lowercase().as_str() {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => bail!("expected on|off, got '{other}'"),
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list of numbers, e.g. `--nodes 2,4,8`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{name}: bad integer '{s}'"))
                })
                .collect(),
        }
    }

    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{name}: bad number '{s}'"))
                })
                .collect(),
        }
    }

    pub fn subcommand(&self) -> Result<&str> {
        self.positional
            .first()
            .map(String::as_str)
            .ok_or_else(|| bail_msg())
    }
}

fn bail_msg() -> anyhow::Error {
    anyhow!("missing subcommand")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse_with(&["nodes"], argv("serve --nodes 4 --verbose --tau=0.2 extra")).unwrap();
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("nodes"), Some("4"));
        assert_eq!(a.get("tau"), Some("0.2"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse_with(&["n", "x", "list"], argv("--n 3 --x 1.5 --list 2,4,8")).unwrap();
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
        assert_eq!(a.f64_or("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.usize_list_or("list", &[]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse_with(&["nodes"], argv("--nodes")).is_err());
        let a = parse_with(&["n"], argv("--n x")).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn on_off_switches() {
        assert!(parse_on_off("on").unwrap());
        assert!(parse_on_off(" ON ").unwrap());
        assert!(parse_on_off("1").unwrap());
        assert!(!parse_on_off("off").unwrap());
        assert!(!parse_on_off("false").unwrap());
        assert!(!parse_on_off("no").unwrap());
        assert!(parse_on_off("maybe").is_err());
        assert!(parse_on_off("").is_err());
    }
}
