//! Reusable hot-path buffers: the steady-state round loop must not
//! allocate (PAPER.md Eq. 4's t2/t3 terms — every `malloc` in
//! `local_work` is drafting/verification throughput reclaimed from the
//! link and thrown away again).
//!
//! A [`RoundScratch`] is an arena of growable buffers owned by whoever
//! drives decode rounds (`OracleChainDecoder`, `DecodeEngine`) and
//! threaded through the draft/verify/commit phases. Buffers are `clear()`ed
//! per use, never dropped, so after a few warmup rounds every one has
//! reached its high-water capacity and the round performs **zero** heap
//! allocations — pinned by `tests/alloc_budget.rs` under the
//! `alloc-count` feature and gated in CI by `benches/hotpath.rs`.
//!
//! Layering: this module holds plain `Vec` buffers only (no model/spec
//! types), so every layer above `util` can take a scratch without a
//! dependency cycle.

/// Buffers for one host verification pass (`spec::reference::
/// host_verify_with`). The vectorized kernel rewire
/// (`crate::kernels`) eliminated the scaled-row copies (`lt`/`ld`), the
/// materialized target row (`p_t` now holds the *raw exponential* row),
/// the log-mixture staging rows, and the greedy blend row — per-slot
/// mixtures and draft distributions land directly in the flat
/// `[gamma, vocab]` stores the correction resample reads.
#[derive(Debug, Clone, Default)]
pub struct VerifyScratch {
    /// Raw target exponential row `exp(t·inv_temp − max)` (also the
    /// bonus-token softmax scratch). The normalized target distribution
    /// is never materialized — only `et[y]·inv_sum_t` is read.
    pub p_t: Vec<f32>,
    /// All mixture rows, `[gamma, vocab]` flattened (correction input).
    pub mix_rows: Vec<f32>,
    /// All draft distribution rows, `[gamma, vocab]` flattened.
    pub pd_rows: Vec<f32>,
    /// Residual distribution for the correction resample.
    pub resid: Vec<f32>,
}

impl VerifyScratch {
    /// Pre-reserve for windows up to `gamma` over a `vocab`-wide model,
    /// so the first verification after this call does not grow anything.
    pub fn reserve(&mut self, gamma: usize, vocab: usize) {
        self.p_t.reserve(vocab);
        self.resid.reserve(vocab);
        self.mix_rows.reserve(gamma * vocab);
        self.pd_rows.reserve(gamma * vocab);
    }
}

/// The full per-sequence round arena: sampling rows, uniform vectors,
/// window/t_logits accumulators, and a small recycling pool for the
/// draft-window `(tokens, logits)` pairs that circulate between the
/// speculate-ahead pre-draft and the next round's draft phase.
#[derive(Debug, Clone, Default)]
pub struct RoundScratch {
    /// Verification buffers (disjoint field so a caller can borrow the
    /// round buffers immutably while verification writes).
    pub verify: VerifyScratch,
    /// Softmax/probability row for sampling.
    pub probs: Vec<f32>,
    /// Logits row (draft or target output of one step).
    pub row: Vec<f32>,
    /// Second logits row (e.g. the target row a synthetic draft row is
    /// correlated against).
    pub row2: Vec<f32>,
    /// Target logits for the whole verify window, `[γ+1, vocab]`.
    pub t_logits: Vec<f32>,
    /// Acceptance uniforms for the round (γ entries).
    pub u_accept: Vec<f32>,
    /// Correction/bonus sampling uniforms (γ+1 entries).
    pub u_sample: Vec<f32>,
    /// Committed-prefix + drafted-continuation token buffer.
    pub chain: Vec<i32>,
    /// Recycled `(tokens, logits)` draft-window pairs. The overlap
    /// scheduler keeps up to [`RoundScratch::SPARE_CAP`] pairs circulating:
    /// one inside the pending `PreDraft`, one inside the in-flight round's
    /// prep, the rest parked here.
    pub spare: Vec<(Vec<i32>, Vec<f32>)>,
}

impl RoundScratch {
    /// Cap on parked draft-window pairs (the overlap cycle needs 2; a
    /// little headroom tolerates discard bursts without unbounded growth).
    pub const SPARE_CAP: usize = 4;

    /// Take a cleared `(tokens, logits)` pair, recycling a parked one
    /// when available.
    pub fn take_pair(&mut self) -> (Vec<i32>, Vec<f32>) {
        match self.spare.pop() {
            Some((mut a, mut b)) => {
                a.clear();
                b.clear();
                (a, b)
            }
            // dsd-lint: allow(hot-path-alloc): pool miss only before the recycle cycle warms (first 2 rounds)
            None => (Vec::new(), Vec::new()),
        }
    }

    /// Park a pair for reuse (dropped instead once the pool is full).
    pub fn recycle_pair(&mut self, mut a: Vec<i32>, mut b: Vec<f32>) {
        if self.spare.len() < Self::SPARE_CAP {
            a.clear();
            b.clear();
            self.spare.push((a, b));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_pool_recycles_capacity() {
        let mut s = RoundScratch::default();
        let (mut a, mut b) = s.take_pair();
        a.extend_from_slice(&[1, 2, 3]);
        b.extend_from_slice(&[0.5; 64]);
        let (cap_a, cap_b) = (a.capacity(), b.capacity());
        s.recycle_pair(a, b);
        let (a2, b2) = s.take_pair();
        assert!(a2.is_empty() && b2.is_empty(), "recycled pairs come back cleared");
        assert_eq!(a2.capacity(), cap_a);
        assert_eq!(b2.capacity(), cap_b);
    }

    #[test]
    fn pair_pool_is_bounded() {
        let mut s = RoundScratch::default();
        for _ in 0..(RoundScratch::SPARE_CAP + 3) {
            s.recycle_pair(Vec::new(), Vec::new());
        }
        assert_eq!(s.spare.len(), RoundScratch::SPARE_CAP);
    }

    #[test]
    fn verify_reserve_prevents_growth() {
        let mut v = VerifyScratch::default();
        v.reserve(8, 64);
        assert!(v.p_t.capacity() >= 64);
        assert!(v.resid.capacity() >= 64);
        assert!(v.mix_rows.capacity() >= 8 * 64);
        assert!(v.pd_rows.capacity() >= 8 * 64);
    }
}
