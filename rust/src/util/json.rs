//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The environment is offline (no serde in the crate cache), so the crate
//! ships its own parser. It supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (the manifest is ASCII anyway) and
//! parses numbers as f64 with an i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; errors carry the key for debuggability.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()
            .and_then(|o| o.get(key))
            .ok_or_else(|| anyhow!("missing key '{key}' in JSON object"))
    }

    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)?
            .as_str()
            .ok_or_else(|| anyhow!("key '{key}' is not a string"))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("key '{key}' is not a usize"))
    }

    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.get(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("key '{key}' is not a number"))
    }

    pub fn usize_array_field(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?
            .as_array()
            .ok_or_else(|| anyhow!("key '{key}' is not an array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("'{key}' element not usize")))
            .collect()
    }
}

impl Value {
    /// Build an object from key/value pairs (bench JSON emitters).
    pub fn obj(pairs: &[(&str, Value)]) -> Value {
        Value::Object(
            pairs
                .iter()
                .map(|(k, v)| (k.to_string(), v.clone()))
                .collect(),
        )
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

impl From<i64> for Value {
    fn from(i: i64) -> Value {
        Value::Int(i)
    }
}

impl From<u64> for Value {
    /// Saturating: values past i64::MAX would otherwise wrap negative.
    fn from(i: u64) -> Value {
        Value::Int(i.min(i64::MAX as u64) as i64)
    }
}

impl From<usize> for Value {
    fn from(i: usize) -> Value {
        Value::Int(i as i64)
    }
}

impl From<f64> for Value {
    /// Non-finite floats have no JSON spelling; emit null instead.
    fn from(x: f64) -> Value {
        if x.is_finite() {
            Value::Float(x)
        } else {
            Value::Null
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}

impl From<Vec<Value>> for Value {
    fn from(a: Vec<Value>) -> Value {
        Value::Array(a)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{:?}", s),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Object(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{:?}:{v}", k)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
        Ok(Value::Object(map))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
        Ok(Value::Array(out))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => break,
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad unicode scalar"))?,
                        );
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Re-decode multi-byte UTF-8 from the raw input.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| anyhow!("invalid UTF-8 in string"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
        Ok(out)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        if s.is_empty() || s == "-" {
            bail!("invalid number at byte {start}");
        }
        if is_float {
            Ok(Value::Float(s.parse::<f64>()?))
        } else {
            match s.parse::<i64>() {
                Ok(i) => Ok(Value::Int(i)),
                Err(_) => Ok(Value::Float(s.parse::<f64>()?)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-3.5").unwrap(), Value::Float(-3.5));
        assert_eq!(parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c\n"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].str_field("b").unwrap(), "c\n");
        assert!(v.get("d").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn parses_utf8_passthrough() {
        assert_eq!(parse("\"τ≈0.2\"").unwrap(), Value::Str("τ≈0.2".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn builders_roundtrip_through_parse() {
        let v = Value::obj(&[
            ("name", "overlap".into()),
            ("speedup", 1.25f64.into()),
            ("rounds", 200usize.into()),
            ("pass", true.into()),
            ("cells", Value::Array(vec![Value::obj(&[("gamma", 4usize.into())])])),
            ("nan_becomes_null", f64::NAN.into()),
        ]);
        let text = v.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back.str_field("name").unwrap(), "overlap");
        assert_eq!(back.f64_field("speedup").unwrap(), 1.25);
        assert_eq!(back.usize_field("rounds").unwrap(), 200);
        assert_eq!(back.get("pass").unwrap(), &Value::Bool(true));
        assert_eq!(back.get("nan_becomes_null").unwrap(), &Value::Null);
        let cells = back.get("cells").unwrap().as_array().unwrap();
        assert_eq!(cells[0].usize_field("gamma").unwrap(), 4);
    }

    #[test]
    fn field_helpers() {
        let v = parse(r#"{"n": 7, "f": 1.5, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(v.usize_field("n").unwrap(), 7);
        assert_eq!(v.f64_field("f").unwrap(), 1.5);
        assert_eq!(v.str_field("s").unwrap(), "x");
        assert_eq!(v.usize_array_field("a").unwrap(), vec![1, 2]);
        assert!(v.get("missing").is_err());
    }
}
