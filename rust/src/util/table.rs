//! Markdown/aligned-text table emitter for the paper-table benches.
//!
//! Every experiment harness prints its rows through this, so the bench
//! output is directly comparable with the paper's tables (and pasteable
//! into EXPERIMENTS.md).

/// Column-aligned table with a markdown-style header.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n## {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `digits` decimals (helper for table rows).
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.00".into()]);
        t.row(vec!["b".into(), "22.50".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| alpha | 1.00  |"));
        assert!(r.contains("| b     | 22.50 |"));
        assert!(r.contains("|-------|"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(2.5612, 2), "2.56");
        assert_eq!(fnum(0.3, 3), "0.300");
    }
}
