//! Counting global allocator behind the `alloc-count` feature — the
//! instrumentation that makes the zero-allocation hot path *durable*:
//! `benches/hotpath.rs` reports allocs/round next to ns/round and fails
//! on budget regression, and `tests/alloc_budget.rs` pins the budget per
//! round kind.
//!
//! With the feature enabled, `lib.rs` installs [`CountingAlloc`] as the
//! `#[global_allocator]`; every `alloc`/`alloc_zeroed`/`realloc` bumps a
//! relaxed atomic (deallocation is free — the budget tracks allocation
//! *events*, the thing that stalls the round loop). Without the feature
//! the module still compiles: [`enabled`] returns `false` and
//! [`measure`] reports zero, so benches print "n/a" instead of lying.

/// Allocation-event count observed by [`measure`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AllocCounts {
    /// Number of allocation events (alloc + alloc_zeroed + realloc).
    pub allocs: u64,
    /// Total bytes requested by those events.
    pub bytes: u64,
}

#[cfg(feature = "alloc-count")]
mod imp {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    pub static ALLOCS: AtomicU64 = AtomicU64::new(0);
    pub static BYTES: AtomicU64 = AtomicU64::new(0);

    /// System allocator wrapper that counts allocation events.
    pub struct CountingAlloc;

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }
}

#[cfg(feature = "alloc-count")]
pub use imp::CountingAlloc;

/// Whether allocation counting is compiled in (the `alloc-count` feature).
pub fn enabled() -> bool {
    cfg!(feature = "alloc-count")
}

/// Current cumulative counts (zeros when counting is disabled).
pub fn current() -> AllocCounts {
    #[cfg(feature = "alloc-count")]
    {
        use std::sync::atomic::Ordering;
        AllocCounts {
            allocs: imp::ALLOCS.load(Ordering::Relaxed),
            bytes: imp::BYTES.load(Ordering::Relaxed),
        }
    }
    #[cfg(not(feature = "alloc-count"))]
    {
        AllocCounts::default()
    }
}

/// Run `f` and report the allocation events it performed (zeros when
/// counting is disabled — check [`enabled`] before asserting on it).
pub fn measure<R, F: FnOnce() -> R>(f: F) -> (R, AllocCounts) {
    let before = current();
    let r = f();
    let after = current();
    let counts = AllocCounts {
        allocs: after.allocs - before.allocs,
        bytes: after.bytes - before.bytes,
    };
    (r, counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_consistently_with_feature() {
        let (v, counts) = measure(|| vec![1u64; 128]);
        assert_eq!(v[0], 1);
        assert_eq!(v.len(), 128);
        if enabled() {
            assert!(counts.allocs >= 1, "a fresh Vec must count: {counts:?}");
            assert!(counts.bytes >= 128 * 8);
        } else {
            assert_eq!(counts, AllocCounts::default());
        }
    }
}
