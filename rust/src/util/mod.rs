//! Self-contained substrates (the build environment is offline; no serde,
//! clap, rand, or criterion in the crate cache — see Cargo.toml).

pub mod alloc_counter;
pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod scratch;
pub mod table;
